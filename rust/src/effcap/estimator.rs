//! Sample-based effective-capacity estimation.

/// Numerically stable `ln( mean( exp(scale * x_i) ) )` in one streaming
/// pass and with no allocation: the running maximum is carried along and
/// the partial sum rescaled whenever it moves (online log-sum-exp). The
/// `scale` factor fuses the `-θ·f` scaling of effective-capacity
/// estimation so g-table construction (`effcap_samples × θ-grid ×
/// y-levels` evaluations) never materializes a scaled sample vector.
pub fn log_mean_exp_scaled(xs: &[f64], scale: f64) -> f64 {
    assert!(!xs.is_empty(), "log_mean_exp over empty slice");
    let mut m = f64::NEG_INFINITY;
    let mut sum = 0.0f64;
    for &x in xs {
        let v = scale * x;
        if v == f64::NEG_INFINITY {
            continue; // exp(v) contributes exactly zero mass
        }
        if v <= m {
            sum += (v - m).exp();
        } else {
            sum = sum * (m - v).exp() + 1.0;
            m = v;
        }
    }
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    m + (sum / xs.len() as f64).ln()
}

/// Numerically stable `ln( mean( exp(x_i) ) )`.
pub fn log_mean_exp(xs: &[f64]) -> f64 {
    log_mean_exp_scaled(xs, 1.0)
}

/// Per-slot effective capacity `Ê^c(θ) = -ln( mean e^{-θ f_i} ) / θ` from
/// iid service-rate samples (eq. 20 specialised to iid slots).
pub fn effective_capacity(rate_samples: &[f64], theta: f64) -> f64 {
    assert!(theta > 0.0, "QoS exponent must be positive");
    -log_mean_exp_scaled(rate_samples, -theta) / theta
}

/// Effective capacity of the *contended* rates `f_i / rate_divisor`
/// (parallelism level `y` scales each draw by `1/y^alpha`), computed
/// without materializing the scaled samples:
/// `E^c = -ln mean exp(-θ f_i / divisor) / θ`.
pub fn effective_capacity_contended(
    rate_samples: &[f64],
    theta: f64,
    rate_divisor: f64,
) -> f64 {
    assert!(theta > 0.0, "QoS exponent must be positive");
    assert!(rate_divisor > 0.0, "rate divisor must be positive");
    -log_mean_exp_scaled(rate_samples, -theta / rate_divisor) / theta
}

/// Reusable estimator over a θ-grid; caches the per-θ capacities for one
/// sample set so g-table construction does one pass per (m, y).
#[derive(Clone, Debug)]
pub struct EffCapEstimator {
    /// Log-spaced QoS exponents.
    pub thetas: Vec<f64>,
}

impl EffCapEstimator {
    /// Log-spaced θ grid on `[lo, hi]` with `n` points.
    pub fn log_grid(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let llo = lo.ln();
        let lhi = hi.ln();
        let thetas = (0..n)
            .map(|i| (llo + (lhi - llo) * i as f64 / (n - 1) as f64).exp())
            .collect();
        EffCapEstimator { thetas }
    }

    /// `Ê^c(θ)` for every θ in the grid.
    pub fn capacities(&self, rate_samples: &[f64]) -> Vec<f64> {
        self.thetas
            .iter()
            .map(|&t| effective_capacity(rate_samples, t))
            .collect()
    }

    /// Invert the tail bound (eq. 21's large-deviation machinery, applied
    /// as an exact Chernoff bound) at violation probability ε for a task
    /// of workload `a_m` (MB) served at the sampled rates.
    ///
    /// The violation event is the service rate's lower tail:
    /// `P{a/f > D} = P{f < a/D} ≤ E[e^{-θf}]·e^{θa/D}
    ///             = exp(θ·(a/D − Ê^c(θ)))`.
    /// Setting the bound to ε gives `D(θ) = a / (Ê^c(θ) + ln(ε)/θ)` when
    /// the denominator is positive; the published bound is `min_θ D(θ)`,
    /// clamped below by the mean-value delay `a/μ` (a statistical delay
    /// bound can never beat the average). Because Chernoff is a true upper
    /// bound, realized violations are guaranteed ≤ ε up to Monte-Carlo
    /// error — property-tested in `effcap::tests`.
    pub fn delay_bound(&self, rate_samples: &[f64], workload_mb: f64, epsilon: f64) -> f64 {
        self.delay_bound_contended(rate_samples, 1.0, workload_mb, epsilon)
    }

    /// [`Self::delay_bound`] over the contended rates `f_i / rate_divisor`
    /// — the g-table inner loop — allocation-free: the divisor is fused
    /// into the streaming log-mean-exp instead of scaling a sample buffer.
    pub fn delay_bound_contended(
        &self,
        rate_samples: &[f64],
        rate_divisor: f64,
        workload_mb: f64,
        epsilon: f64,
    ) -> f64 {
        assert!((0.0..1.0).contains(&epsilon) && epsilon > 0.0);
        assert!(rate_divisor > 0.0, "rate divisor must be positive");
        let n = rate_samples.len() as f64;
        let mu: f64 = rate_samples.iter().sum::<f64>() / n / rate_divisor;
        let mean_delay = workload_mb / mu;
        let ln_eps = epsilon.ln(); // < 0
        let mut best = f64::INFINITY;
        for &theta in &self.thetas {
            let ec = effective_capacity_contended(rate_samples, theta, rate_divisor);
            let denom = ec + ln_eps / theta;
            if denom <= 0.0 {
                continue; // θ too small: bound vacuous at this exponent
            }
            let d = workload_mb / denom;
            if d < best {
                best = d;
            }
        }
        best.max(mean_delay)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_grid_is_log_spaced_and_inclusive() {
        let e = EffCapEstimator::log_grid(1e-3, 10.0, 5);
        assert_eq!(e.thetas.len(), 5);
        assert!((e.thetas[0] - 1e-3).abs() < 1e-12);
        assert!((e.thetas[4] - 10.0).abs() < 1e-9);
        // constant ratio
        let r1 = e.thetas[1] / e.thetas[0];
        let r2 = e.thetas[3] / e.thetas[2];
        assert!((r1 - r2).abs() < 1e-9);
    }

    #[test]
    fn deterministic_rates_have_capacity_equal_rate() {
        let samples = vec![5.0; 1000];
        for theta in [0.01, 1.0, 5.0] {
            let e = effective_capacity(&samples, theta);
            assert!((e - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_delay_bound_approaches_mean_delay() {
        // With f ≡ 4 the exact delay is 0.5; the Chernoff bound converges
        // to it as θ_hi grows (the ln(ε)/θ slack vanishes).
        let samples = vec![4.0; 256];
        let est = EffCapEstimator::log_grid(1e-3, 1e4, 64);
        let d = est.delay_bound(&samples, 2.0, 0.2);
        assert!(d >= 0.5 && d - 0.5 < 1e-3, "got {d}");
    }

    #[test]
    fn delay_bound_decreasing_in_epsilon() {
        let samples: Vec<f64> = (0..2048)
            .map(|i| 1.0 + (i % 17) as f64 * 0.7)
            .collect();
        let est = EffCapEstimator::log_grid(1e-3, 10.0, 32);
        let d1 = est.delay_bound(&samples, 1.0, 0.05);
        let d2 = est.delay_bound(&samples, 1.0, 0.2);
        let d3 = est.delay_bound(&samples, 1.0, 0.6);
        assert!(d1 >= d2 && d2 >= d3);
    }

    #[test]
    #[should_panic]
    fn zero_theta_rejected() {
        effective_capacity(&[1.0], 0.0);
    }

    #[test]
    fn scaled_log_mean_exp_matches_materialized() {
        let xs: Vec<f64> = (0..257).map(|i| 0.3 + (i % 23) as f64 * 0.7).collect();
        for scale in [-2.5, -0.01, 0.4, 1.0] {
            let materialized: Vec<f64> = xs.iter().map(|&x| scale * x).collect();
            let want = {
                let m = materialized.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let s: f64 = materialized.iter().map(|&v| (v - m).exp()).sum();
                m + (s / xs.len() as f64).ln()
            };
            let got = log_mean_exp_scaled(&xs, scale);
            assert!(
                (got - want).abs() < 1e-12,
                "scale={scale}: got={got} want={want}"
            );
        }
    }

    #[test]
    fn contended_capacity_matches_scaled_samples() {
        let samples: Vec<f64> = (0..1024).map(|i| 1.0 + (i % 13) as f64 * 0.9).collect();
        for y in [1.0f64, 2.0, 7.5] {
            let scaled: Vec<f64> = samples.iter().map(|&f| f / y).collect();
            for theta in [0.05, 0.8, 3.0] {
                let direct = effective_capacity(&scaled, theta);
                let fused = effective_capacity_contended(&samples, theta, y);
                assert!(
                    (direct - fused).abs() < 1e-10,
                    "y={y} theta={theta}: {direct} vs {fused}"
                );
            }
        }
    }

    #[test]
    fn contended_delay_bound_matches_scaled_samples() {
        let samples: Vec<f64> = (0..512).map(|i| 2.0 + (i % 7) as f64).collect();
        let est = EffCapEstimator::log_grid(1e-3, 10.0, 24);
        for y in [1.0f64, 3.0, 9.0] {
            let scaled: Vec<f64> = samples.iter().map(|&f| f / y).collect();
            let direct = est.delay_bound(&scaled, 1.3, 0.2);
            let fused = est.delay_bound_contended(&samples, y, 1.3, 0.2);
            assert!(
                (direct - fused).abs() < 1e-9,
                "y={y}: {direct} vs {fused}"
            );
        }
    }
}
