//! Minimal TOML-subset parser (serde/toml unavailable offline).
//!
//! Supported: `[table.subtable]` headers, `key = value` pairs with string,
//! integer, float, boolean and homogeneous-array values, comments (`#`),
//! and blank lines. This covers everything the experiment configuration
//! files in `configs/` use.

use std::collections::BTreeMap;

/// Parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    /// Numeric view (integers widen to f64).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Integer(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, TomlValue>> {
        match self {
            TomlValue::Table(t) => Some(t),
            _ => None,
        }
    }

    /// Dotted-path lookup: `get_path("controller.eta")`.
    pub fn get_path(&self, path: &str) -> Option<&TomlValue> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }

    /// `[lo, hi]` two-element numeric array.
    pub fn as_range(&self) -> Option<(f64, f64)> {
        let a = self.as_array()?;
        if a.len() != 2 {
            return None;
        }
        Some((a[0].as_f64()?, a[1].as_f64()?))
    }
}

/// Parse error with line information.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TOML parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, message: impl Into<String>) -> TomlError {
    TomlError {
        line,
        message: message.into(),
    }
}

/// Parse a TOML document into a root table.
pub fn parse(input: &str) -> Result<TomlValue, TomlError> {
    let mut root = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| err(lineno + 1, "unterminated table header"))?;
            if header.is_empty() {
                return Err(err(lineno + 1, "empty table header"));
            }
            current_path = header.split('.').map(|s| s.trim().to_string()).collect();
            if current_path.iter().any(String::is_empty) {
                return Err(err(lineno + 1, "empty table path component"));
            }
            // Materialize intermediate tables.
            ensure_table(&mut root, &current_path, lineno + 1)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno + 1, "expected `key = value`"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno + 1, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno + 1)?;
        let table = ensure_table(&mut root, &current_path, lineno + 1)?;
        if table.insert(key.to_string(), value).is_some() {
            return Err(err(lineno + 1, format!("duplicate key `{key}`")));
        }
    }
    Ok(TomlValue::Table(root))
}

fn strip_comment(line: &str) -> &str {
    // Track whether we are inside a string to avoid cutting "#" in strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
    line: usize,
) -> Result<&'a mut BTreeMap<String, TomlValue>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        match entry {
            TomlValue::Table(t) => cur = t,
            _ => return Err(err(line, format!("`{part}` is not a table"))),
        }
    }
    Ok(cur)
}

fn parse_value(s: &str, line: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(err(line, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        if !rest[end + 1..].trim().is_empty() {
            return Err(err(line, "trailing characters after string"));
        }
        return Ok(TomlValue::String(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?;
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, line)?);
        }
        return Ok(TomlValue::Array(items));
    }
    // Number: integer if no '.', 'e', or 'E'.
    if s.contains('.') || s.contains('e') || s.contains('E') {
        s.parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| err(line, format!("invalid float `{s}`")))
    } else {
        s.parse::<i64>()
            .map(TomlValue::Integer)
            .map_err(|_| err(line, format!("invalid integer `{s}`")))
    }
}

/// Split a (possibly nested) array body on top-level commas.
fn split_array_items(s: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&s[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = r#"
# experiment config
title = "paper"
trials = 40
eta = 0.5
flag = true

[network]
num_eds = 12
num_ess = 4

[network.wireless]
bandwidth = [0.1, 1.0]
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get_path("title").unwrap().as_str().unwrap(), "paper");
        assert_eq!(v.get_path("trials").unwrap().as_i64().unwrap(), 40);
        assert!((v.get_path("eta").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(v.get_path("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get_path("network.num_eds").unwrap().as_i64(), Some(12));
        let (lo, hi) = v
            .get_path("network.wireless.bandwidth")
            .unwrap()
            .as_range()
            .unwrap();
        assert_eq!((lo, hi), (0.1, 1.0));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let v = parse(r##"k = "a # b""##).unwrap();
        assert_eq!(v.get_path("k").unwrap().as_str().unwrap(), "a # b");
    }

    #[test]
    fn nested_arrays() {
        let v = parse("m = [[1, 2], [3, 4]]").unwrap();
        let outer = v.get_path("m").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_array().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_header_rejected() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("[]").is_err());
    }

    #[test]
    fn missing_equals_rejected() {
        let e = parse("justakey").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let v = parse("a = -3\nb = 1.5e-3").unwrap();
        assert_eq!(v.get_path("a").unwrap().as_i64(), Some(-3));
        assert!((v.get_path("b").unwrap().as_f64().unwrap() - 1.5e-3).abs() < 1e-15);
    }
}
