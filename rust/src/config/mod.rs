//! Experiment configuration: Table I parameter ranges, typed config
//! structs, TOML loading with validation, and the paper's default values.
//!
//! "Key parameters are listed in TABLE I; values for each run are sampled
//! from predefined ranges" (§IV) — [`Range`] models exactly that: each
//! trial samples concrete values uniformly from its range.

pub mod toml;

use crate::rng::Rng;
use toml::{TomlError, TomlValue};

/// Number of resource dimensions (CPU, RAM, GPU, VRAM — Table I).
pub const NUM_RESOURCES: usize = 4;

/// Resource dimension names, index-aligned with all `[f64; NUM_RESOURCES]`.
pub const RESOURCE_NAMES: [&str; NUM_RESOURCES] = ["CPU", "RAM", "GPU", "VRAM"];

/// Closed interval `[lo, hi]` sampled uniformly per run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Range {
    pub lo: f64,
    pub hi: f64,
}

impl Range {
    pub const fn new(lo: f64, hi: f64) -> Self {
        Range { lo, hi }
    }

    /// Degenerate single-value range.
    pub const fn fixed(v: f64) -> Self {
        Range { lo: v, hi: v }
    }

    /// Uniform sample.
    pub fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        if self.lo == self.hi {
            self.lo
        } else {
            rng.range_f64(self.lo, self.hi)
        }
    }

    /// Midpoint — used by mean-value analyses.
    pub fn mid(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }

    fn validate(&self, name: &str) -> Result<(), ConfigError> {
        if !self.lo.is_finite() || !self.hi.is_finite() || self.hi < self.lo {
            return Err(ConfigError::Invalid(format!(
                "range `{name}` invalid: [{}, {}]",
                self.lo, self.hi
            )));
        }
        Ok(())
    }
}

/// Per-resource sampling ranges.
pub type ResourceRanges = [Range; NUM_RESOURCES];

/// Processing-rate model of a microservice class (§II-A): deterministic for
/// core MSs, Gamma-distributed under contention for light MSs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateSpec {
    /// Deterministic rate sampled once per run from the range (MB/ms).
    Deterministic(Range),
    /// `Gamma(shape, scale)`; both hyper-parameters sampled per MS per run.
    Gamma { shape: Range, scale: Range },
}

/// Per-class microservice configuration (Table I rows "Core MS"/"Light MS").
#[derive(Clone, Copy, Debug)]
pub struct MsClassConfig {
    /// Resource requirement ranges `r_{m,k}`.
    pub resources: ResourceRanges,
    /// Computational workload `a_m` (MB).
    pub workload_mb: Range,
    /// Output size `b_m` (MB).
    pub output_mb: Range,
    /// Processing rate `f_m` (MB/ms).
    pub rate: RateSpec,
    /// One-time deployment price `c^dp`.
    pub cost_deploy: f64,
    /// Per-slot maintenance price `c^mt`.
    pub cost_maint: f64,
    /// Per-parallelism price `c^pl` (light MSs only in the paper).
    pub cost_parallel: f64,
}

/// Per-class node capacity ranges (Table I rows "ED"/"ES").
#[derive(Clone, Copy, Debug)]
pub struct NodeClassConfig {
    pub resources: ResourceRanges,
}

/// Edge network shape and link parameters.
#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    /// Number of edge devices (user-facing).
    pub num_eds: usize,
    /// Number of edge servers (backbone).
    pub num_ess: usize,
    /// Link bandwidth `w` (MB/ms).
    pub link_bandwidth: Range,
    /// Link distance `W` (km).
    pub link_distance_km: Range,
    /// Propagation speed `l` (km/ms); ~200 km/ms in fiber.
    pub prop_speed_km_per_ms: f64,
    /// Extra ED↔ES attachment links per ED beyond its primary (mesh degree).
    pub ed_extra_links: usize,
}

/// User population and task-arrival stochastics (Table I bottom row).
#[derive(Clone, Copy, Debug)]
pub struct WorkloadConfig {
    /// Number of users `|U|`.
    pub num_users: usize,
    /// Poisson arrival mean `z_{u,n,t}` per slot, per (user, task type).
    pub arrival_rate: Range,
    /// End-to-end deadline `D_n` (ms).
    pub deadline_ms: Range,
    /// Task input payload `A_n` (MB).
    pub input_mb: Range,
    /// Nakagami fading shape `m` for the uplink SNR.
    pub nakagami_m: Range,
    /// Nakagami spread Ω (mean channel power).
    pub nakagami_omega: Range,
    /// Per-user uplink bandwidth `b_u` (MB/ms at unit spectral efficiency).
    pub uplink_bandwidth: Range,
    /// Mean SNR scaling (linear) applied to the fading power.
    pub mean_snr: Range,
}

/// Application shape (Fig. 1): task-type DAGs over core + light MSs.
#[derive(Clone, Copy, Debug)]
pub struct AppConfig {
    pub num_task_types: usize,
    pub num_core_ms: usize,
    pub num_light_ms: usize,
    /// Microservices per task DAG (inverse tree), range.
    pub services_per_task: Range,
}

/// Two-tier deployment strategy knobs (§III).
#[derive(Clone, Copy, Debug)]
pub struct ControllerConfig {
    /// Latency-violation probability ε for the effective-capacity map.
    pub epsilon: f64,
    /// Lyapunov cost weight η in (19).
    pub eta: f64,
    /// Virtual-queue floor ζ in (18).
    pub zeta: f64,
    /// Task priority weight φ (uniform across tasks by default).
    pub phi: f64,
    /// QoS-score weight ξ in (14).
    pub xi: f64,
    /// Load-apportionment decay δ in (15).
    pub delta: f64,
    /// Urgency cap C1 in (16).
    pub urgency_cap: f64,
    /// Minimum distinct core deployments κ (C6).
    pub kappa: usize,
    /// Big-M constant C2 (C4) — max instances per (node, MS).
    pub big_m: f64,
    /// θ-grid for the effective-capacity search: [lo, hi] with `theta_n`
    /// log-spaced points.
    pub theta_lo: f64,
    pub theta_hi: f64,
    pub theta_n: usize,
    /// Monte-Carlo samples per light MS for Ê^c(θ).
    pub effcap_samples: usize,
    /// Maximum parallelism level tabulated in `g_{m,ε}(y)`.
    pub max_parallelism: usize,
    /// Contention exponent: per-task rate is `f / y^alpha`.
    pub contention_alpha: f64,
}

/// Simulation horizon and trial control.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Slots in the horizon |T|.
    pub slots: usize,
    /// Slot length (ms).
    pub slot_ms: f64,
    /// Base RNG seed; trial `i` uses `seed + i`.
    pub seed: u64,
    /// Number of independent trials (Fig. 3 violins).
    pub trials: usize,
    /// Arrival-mean multiplier (Fig. 4 escalating load).
    pub load_multiplier: f64,
}

/// Root experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub network: NetworkConfig,
    pub workload: WorkloadConfig,
    pub app: AppConfig,
    pub core_ms: MsClassConfig,
    pub light_ms: MsClassConfig,
    pub ed: NodeClassConfig,
    pub es: NodeClassConfig,
    pub controller: ControllerConfig,
    pub sim: SimConfig,
}

/// Configuration errors.
#[derive(Debug)]
pub enum ConfigError {
    Parse(TomlError),
    Invalid(String),
    Io(std::io::Error),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "{e}"),
            ConfigError::Invalid(s) => write!(f, "invalid config: {s}"),
            ConfigError::Io(e) => write!(f, "config io error: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<TomlError> for ConfigError {
    fn from(e: TomlError) -> Self {
        ConfigError::Parse(e)
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl ExperimentConfig {
    /// The paper's Table I defaults: 4 task types, 6 core MSs, 9 light MSs,
    /// ε = 0.2.
    pub fn paper_default() -> Self {
        ExperimentConfig {
            network: NetworkConfig {
                num_eds: 12,
                num_ess: 4,
                link_bandwidth: Range::new(0.1, 1.0),
                link_distance_km: Range::new(0.2, 5.0),
                prop_speed_km_per_ms: 200.0,
                ed_extra_links: 1,
            },
            workload: WorkloadConfig {
                num_users: 10,
                arrival_rate: Range::new(0.15, 1.5),
                deadline_ms: Range::new(50.0, 100.0),
                input_mb: Range::new(0.5, 4.0),
                nakagami_m: Range::new(1.5, 3.0),
                nakagami_omega: Range::new(0.5, 1.0),
                uplink_bandwidth: Range::new(0.5, 2.0),
                mean_snr: Range::new(10.0, 100.0),
            },
            app: AppConfig {
                num_task_types: 4,
                num_core_ms: 6,
                num_light_ms: 9,
                services_per_task: Range::new(5.0, 8.0),
            },
            core_ms: MsClassConfig {
                resources: [
                    Range::new(2.0, 16.0),
                    Range::new(1.0, 4.0),
                    Range::new(4.0, 32.0),
                    Range::new(4.0, 32.0),
                ],
                workload_mb: Range::new(2.0, 16.0),
                output_mb: Range::new(0.1, 1.0),
                rate: RateSpec::Deterministic(Range::new(8.0, 32.0)),
                cost_deploy: 20.0,
                cost_maint: 4.0,
                cost_parallel: 0.0,
            },
            light_ms: MsClassConfig {
                resources: [
                    Range::new(0.5, 2.0),
                    Range::new(0.0, 0.5),
                    Range::new(0.25, 4.0),
                    Range::new(0.0, 1.0),
                ],
                workload_mb: Range::new(0.5, 2.0),
                output_mb: Range::new(0.25, 1.5),
                rate: RateSpec::Gamma {
                    shape: Range::new(1.0, 2.0),
                    scale: Range::new(1.0, 20.0),
                },
                cost_deploy: 4.0,
                cost_maint: 1.0,
                cost_parallel: 0.5,
            },
            ed: NodeClassConfig {
                resources: [
                    Range::new(1.0, 64.0),
                    Range::new(1.0, 32.0),
                    Range::new(0.0, 64.0),
                    Range::new(0.0, 64.0),
                ],
            },
            es: NodeClassConfig {
                resources: [
                    Range::new(128.0, 256.0),
                    Range::new(64.0, 128.0),
                    Range::new(1024.0, 2048.0),
                    Range::new(256.0, 512.0),
                ],
            },
            controller: ControllerConfig {
                epsilon: 0.2,
                eta: 1.0,
                zeta: 0.5,
                phi: 1.0,
                xi: 1.0,
                delta: 0.05,
                urgency_cap: 4.0,
                kappa: 8,
                big_m: 64.0,
                theta_lo: 1e-3,
                theta_hi: 10.0,
                theta_n: 32,
                effcap_samples: 4096,
                max_parallelism: 16,
                contention_alpha: 1.0,
            },
            sim: SimConfig {
                slots: 500,
                slot_ms: 1.0,
                seed: 2026,
                trials: 40,
                load_multiplier: 1.0,
            },
        }
    }

    /// Load from a TOML string, starting from [`Self::paper_default`] and
    /// overriding any key present in the document.
    pub fn from_toml_str(doc: &str) -> Result<Self, ConfigError> {
        let v = toml::parse(doc)?;
        let mut cfg = Self::paper_default();
        cfg.apply_overrides(&v)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_path(path: &str) -> Result<Self, ConfigError> {
        let doc = std::fs::read_to_string(path)?;
        Self::from_toml_str(&doc)
    }

    fn apply_overrides(&mut self, v: &TomlValue) -> Result<(), ConfigError> {
        fn set_usize(v: &TomlValue, path: &str, dst: &mut usize) -> Result<(), ConfigError> {
            if let Some(x) = v.get_path(path) {
                *dst = x
                    .as_i64()
                    .filter(|&i| i >= 0)
                    .ok_or_else(|| {
                        ConfigError::Invalid(format!("`{path}` must be a non-negative integer"))
                    })? as usize;
            }
            Ok(())
        }
        fn set_f64(v: &TomlValue, path: &str, dst: &mut f64) -> Result<(), ConfigError> {
            if let Some(x) = v.get_path(path) {
                *dst = x
                    .as_f64()
                    .ok_or_else(|| ConfigError::Invalid(format!("`{path}` must be numeric")))?;
            }
            Ok(())
        }
        fn set_u64(v: &TomlValue, path: &str, dst: &mut u64) -> Result<(), ConfigError> {
            if let Some(x) = v.get_path(path) {
                *dst = x
                    .as_i64()
                    .filter(|&i| i >= 0)
                    .ok_or_else(|| {
                        ConfigError::Invalid(format!("`{path}` must be a non-negative integer"))
                    })? as u64;
            }
            Ok(())
        }
        fn set_range(v: &TomlValue, path: &str, dst: &mut Range) -> Result<(), ConfigError> {
            if let Some(x) = v.get_path(path) {
                let (lo, hi) = x
                    .as_range()
                    .ok_or_else(|| ConfigError::Invalid(format!("`{path}` must be [lo, hi]")))?;
                *dst = Range::new(lo, hi);
            }
            Ok(())
        }

        set_usize(v, "network.num_eds", &mut self.network.num_eds)?;
        set_usize(v, "network.num_ess", &mut self.network.num_ess)?;
        set_range(v, "network.link_bandwidth", &mut self.network.link_bandwidth)?;
        set_range(v, "network.link_distance_km", &mut self.network.link_distance_km)?;
        set_f64(
            v,
            "network.prop_speed_km_per_ms",
            &mut self.network.prop_speed_km_per_ms,
        )?;
        set_usize(v, "network.ed_extra_links", &mut self.network.ed_extra_links)?;

        set_usize(v, "workload.num_users", &mut self.workload.num_users)?;
        set_range(v, "workload.arrival_rate", &mut self.workload.arrival_rate)?;
        set_range(v, "workload.deadline_ms", &mut self.workload.deadline_ms)?;
        set_range(v, "workload.input_mb", &mut self.workload.input_mb)?;
        set_range(v, "workload.nakagami_m", &mut self.workload.nakagami_m)?;
        set_range(v, "workload.nakagami_omega", &mut self.workload.nakagami_omega)?;
        set_range(v, "workload.uplink_bandwidth", &mut self.workload.uplink_bandwidth)?;
        set_range(v, "workload.mean_snr", &mut self.workload.mean_snr)?;

        set_usize(v, "app.num_task_types", &mut self.app.num_task_types)?;
        set_usize(v, "app.num_core_ms", &mut self.app.num_core_ms)?;
        set_usize(v, "app.num_light_ms", &mut self.app.num_light_ms)?;
        set_range(v, "app.services_per_task", &mut self.app.services_per_task)?;

        set_f64(v, "controller.epsilon", &mut self.controller.epsilon)?;
        set_f64(v, "controller.eta", &mut self.controller.eta)?;
        set_f64(v, "controller.zeta", &mut self.controller.zeta)?;
        set_f64(v, "controller.phi", &mut self.controller.phi)?;
        set_f64(v, "controller.xi", &mut self.controller.xi)?;
        set_f64(v, "controller.delta", &mut self.controller.delta)?;
        set_f64(v, "controller.urgency_cap", &mut self.controller.urgency_cap)?;
        set_usize(v, "controller.kappa", &mut self.controller.kappa)?;
        set_f64(v, "controller.big_m", &mut self.controller.big_m)?;
        set_f64(v, "controller.theta_lo", &mut self.controller.theta_lo)?;
        set_f64(v, "controller.theta_hi", &mut self.controller.theta_hi)?;
        set_usize(v, "controller.theta_n", &mut self.controller.theta_n)?;
        set_usize(v, "controller.effcap_samples", &mut self.controller.effcap_samples)?;
        set_usize(v, "controller.max_parallelism", &mut self.controller.max_parallelism)?;
        set_f64(
            v,
            "controller.contention_alpha",
            &mut self.controller.contention_alpha,
        )?;

        set_usize(v, "sim.slots", &mut self.sim.slots)?;
        set_f64(v, "sim.slot_ms", &mut self.sim.slot_ms)?;
        set_u64(v, "sim.seed", &mut self.sim.seed)?;
        set_usize(v, "sim.trials", &mut self.sim.trials)?;
        set_f64(v, "sim.load_multiplier", &mut self.sim.load_multiplier)?;
        Ok(())
    }

    /// Sanity-check all parameters.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let c = &self.controller;
        if !(0.0 < c.epsilon && c.epsilon < 1.0) {
            return Err(ConfigError::Invalid(format!(
                "epsilon must be in (0,1), got {}",
                c.epsilon
            )));
        }
        if c.zeta < 0.0 || c.eta < 0.0 || c.xi < 0.0 {
            return Err(ConfigError::Invalid(
                "eta, zeta, xi must be non-negative".into(),
            ));
        }
        if c.theta_lo <= 0.0 || c.theta_hi <= c.theta_lo || c.theta_n < 2 {
            return Err(ConfigError::Invalid("bad theta grid".into()));
        }
        if c.max_parallelism == 0 || c.effcap_samples == 0 {
            return Err(ConfigError::Invalid(
                "max_parallelism and effcap_samples must be positive".into(),
            ));
        }
        if self.network.num_eds == 0 || self.network.num_ess == 0 {
            return Err(ConfigError::Invalid(
                "network needs at least 1 ED and 1 ES".into(),
            ));
        }
        if self.app.num_task_types == 0 || self.app.num_core_ms == 0 || self.app.num_light_ms == 0
        {
            return Err(ConfigError::Invalid("app shape must be non-zero".into()));
        }
        if self.sim.slots == 0 || self.sim.slot_ms <= 0.0 {
            return Err(ConfigError::Invalid("sim horizon must be positive".into()));
        }
        if self.sim.load_multiplier <= 0.0 {
            return Err(ConfigError::Invalid("load multiplier must be positive".into()));
        }
        for (r, name) in [
            (&self.network.link_bandwidth, "network.link_bandwidth"),
            (&self.workload.arrival_rate, "workload.arrival_rate"),
            (&self.workload.deadline_ms, "workload.deadline_ms"),
            (&self.workload.input_mb, "workload.input_mb"),
        ] {
            r.validate(name)?;
            if r.lo < 0.0 {
                return Err(ConfigError::Invalid(format!("`{name}` must be non-negative")));
            }
        }
        Ok(())
    }

    /// Human-readable dump (the `fmedge config --show` output; reproduces
    /// Table I).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        s.push_str("Table I — experiment parameters (sampled per run)\n");
        let fmt_r = |r: &Range| format!("[{}, {}]", r.lo, r.hi);
        s.push_str(&format!(
            "Core MS : r={:?} a={} b={} f={:?} c=({}, {}, {})\n",
            self.core_ms.resources.iter().map(fmt_r).collect::<Vec<_>>(),
            fmt_r(&self.core_ms.workload_mb),
            fmt_r(&self.core_ms.output_mb),
            self.core_ms.rate,
            self.core_ms.cost_deploy,
            self.core_ms.cost_maint,
            self.core_ms.cost_parallel
        ));
        s.push_str(&format!(
            "Light MS: r={:?} a={} b={} f={:?} c=({}, {}, {})\n",
            self.light_ms.resources.iter().map(fmt_r).collect::<Vec<_>>(),
            fmt_r(&self.light_ms.workload_mb),
            fmt_r(&self.light_ms.output_mb),
            self.light_ms.rate,
            self.light_ms.cost_deploy,
            self.light_ms.cost_maint,
            self.light_ms.cost_parallel
        ));
        s.push_str(&format!(
            "ED caps : {:?}\nES caps : {:?}\n",
            self.ed.resources.iter().map(fmt_r).collect::<Vec<_>>(),
            self.es.resources.iter().map(fmt_r).collect::<Vec<_>>()
        ));
        s.push_str(&format!(
            "Workload: z~Poisson({}) D={}ms gamma~Nakagami({}, {}) A={}MB\n",
            fmt_r(&self.workload.arrival_rate),
            fmt_r(&self.workload.deadline_ms),
            fmt_r(&self.workload.nakagami_m),
            fmt_r(&self.workload.nakagami_omega),
            fmt_r(&self.workload.input_mb)
        ));
        s.push_str(&format!(
            "Network : |ED|={} |ES|={} w={}MB/ms\n",
            self.network.num_eds,
            self.network.num_ess,
            fmt_r(&self.network.link_bandwidth)
        ));
        s.push_str(&format!(
            "Control : eps={} eta={} zeta={} xi={} delta={} kappa={}\n",
            self.controller.epsilon,
            self.controller.eta,
            self.controller.zeta,
            self.controller.xi,
            self.controller.delta,
            self.controller.kappa
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn paper_default_is_valid() {
        ExperimentConfig::paper_default().validate().unwrap();
    }

    #[test]
    fn paper_default_matches_table_one() {
        let c = ExperimentConfig::paper_default();
        assert_eq!(c.app.num_task_types, 4);
        assert_eq!(c.app.num_core_ms, 6);
        assert_eq!(c.app.num_light_ms, 9);
        assert_eq!(c.controller.epsilon, 0.2);
        assert_eq!(c.core_ms.cost_deploy, 20.0);
        assert_eq!(c.core_ms.cost_maint, 4.0);
        assert_eq!(c.light_ms.cost_deploy, 4.0);
        assert_eq!(c.light_ms.cost_parallel, 0.5);
        assert_eq!(c.workload.arrival_rate, Range::new(0.15, 1.5));
        assert_eq!(c.workload.deadline_ms, Range::new(50.0, 100.0));
        match c.light_ms.rate {
            RateSpec::Gamma { shape, scale } => {
                assert_eq!(shape, Range::new(1.0, 2.0));
                assert_eq!(scale, Range::new(1.0, 20.0));
            }
            _ => panic!("light MS rate must be Gamma"),
        }
    }

    #[test]
    fn toml_overrides_apply() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
[sim]
slots = 100
trials = 3
load_multiplier = 1.5

[controller]
epsilon = 0.1
kappa = 5

[workload]
arrival_rate = [0.3, 0.9]
"#,
        )
        .unwrap();
        assert_eq!(cfg.sim.slots, 100);
        assert_eq!(cfg.sim.trials, 3);
        assert_eq!(cfg.sim.load_multiplier, 1.5);
        assert_eq!(cfg.controller.epsilon, 0.1);
        assert_eq!(cfg.controller.kappa, 5);
        assert_eq!(cfg.workload.arrival_rate, Range::new(0.3, 0.9));
        // untouched defaults survive
        assert_eq!(cfg.app.num_core_ms, 6);
    }

    #[test]
    fn invalid_epsilon_rejected() {
        let r = ExperimentConfig::from_toml_str("[controller]\nepsilon = 1.5");
        assert!(r.is_err());
    }

    #[test]
    fn invalid_range_rejected() {
        let r = ExperimentConfig::from_toml_str("[workload]\narrival_rate = [2.0, 1.0]");
        assert!(r.is_err());
    }

    #[test]
    fn range_sampling_within_bounds() {
        let mut rng = Xoshiro256::seed_from(1);
        let r = Range::new(3.0, 7.0);
        for _ in 0..1000 {
            let v = r.sample(&mut rng);
            assert!((3.0..7.0).contains(&v));
        }
        assert_eq!(Range::fixed(5.0).sample(&mut rng), 5.0);
        assert_eq!(r.mid(), 5.0);
    }

    #[test]
    fn describe_mentions_key_rows() {
        let d = ExperimentConfig::paper_default().describe();
        assert!(d.contains("Core MS"));
        assert!(d.contains("Light MS"));
        assert!(d.contains("Nakagami"));
    }
}
