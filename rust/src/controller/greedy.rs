//! Algorithm 1 — greedy online light-MS deployment.
//!
//! Each slot, starting from the busy instances carried over from the
//! previous slot, the controller repeatedly applies the single incremental
//! deployment (one instance of light MS `m` on node `v`) with the most
//! negative marginal drift-plus-penalty `Δ_{v,m}L` (eq. 19), where each
//! queued task is routed to the instance minimizing its next-hop latency
//! `ΔT_j = τ_tr + τ_pp + g_{m,ε}(y+1)`. The loop stops when no deployment
//! is cost-effective. Per-slot complexity is `O(M·(1 + |Jqu|·|V|·|Mlt|))`
//! with `M` greedy iterations — the paper's bound. (Implementation note:
//! queued tasks are partitioned by their required service, so after
//! committing an instance of `m*` only `m*`'s candidates change; the
//! other services' marginals are cached and only re-validated against the
//! consumed node capacity, and each candidate is scored in O(|J_m|) via
//! prefix sums — see EXPERIMENTS.md §Perf.)

use crate::config::NUM_RESOURCES;
use crate::effcap::GTable;
use crate::routing::DistanceMatrix;

use super::OnlineParams;

/// A task waiting for its next (light) service.
#[derive(Clone, Copy, Debug)]
pub struct LightRequest {
    pub task_id: u64,
    /// Dense light-MS index of the needed service.
    pub light_idx: usize,
    /// Node currently holding the task's payload (`v_j`).
    pub from_node: usize,
    /// Payload size to move (MB).
    pub payload_mb: f64,
    /// Lyapunov queue value `H_j(t)`.
    pub h: f64,
    /// Remaining deadline budget (ms) — diagnostics only.
    pub deadline_slack_ms: f64,
}

/// Final routing of one request.
#[derive(Clone, Copy, Debug)]
pub struct Assignment {
    pub node: usize,
    pub light_idx: usize,
    /// Parallelism level of the chosen instance-group *after* assignment —
    /// the realized contended delay uses this `y`.
    pub y: u32,
    /// Network component of ΔT (ms).
    pub transfer_ms: f64,
    /// QoS-bound processing estimate `g(y)` used in the decision (ms).
    pub est_proc_ms: f64,
}

/// The slot's decision: instance counts, parallelism, routing.
#[derive(Clone, Debug)]
pub struct LightDecision {
    /// `x[v][m]` — light instances this slot (busy carryover + new).
    pub x: Vec<Vec<u32>>,
    /// `y[v][m]` — concurrent tasks assigned per (node, MS) this slot.
    pub y: Vec<Vec<u32>>,
    /// Per-request routing (same order as the input queue).
    pub assignments: Vec<Option<Assignment>>,
    pub stats: GreedyStats,
}

/// Greedy-loop statistics for `bench_alg1`.
#[derive(Clone, Copy, Debug, Default)]
pub struct GreedyStats {
    pub iterations: usize,
    pub instances_added: usize,
    pub candidates_scanned: usize,
    /// Final drift-plus-penalty value (19) under the decision.
    pub objective: f64,
}

/// Capacity (concurrent tasks) of `x` instances at max parallelism.
#[inline]
fn group_capacity(x: u32, max_y: usize) -> u32 {
    x.saturating_mul(max_y as u32)
}

/// Run Algorithm 1 for one slot. See module docs; arguments:
///
/// * `queue` — tasks awaiting a light service (`J^qu(t)`).
/// * `busy` — instance counts still processing previous-slot work
///   (`x^{lt,bs}_{t-1}`); kept deployed for free continuation.
/// * `residual` — per-node capacity left for *new* instances.
/// * `resources` — per light MS resource requirement vectors.
/// * `costs` — per light MS `(c_dp, c_mt, c_pl)`.
#[allow(clippy::too_many_arguments)]
pub fn greedy_light_deployment(
    queue: &[LightRequest],
    busy: &[Vec<u32>],
    residual: &[[f64; NUM_RESOURCES]],
    resources: &[[f64; NUM_RESOURCES]],
    costs: &[(f64, f64, f64)],
    gtable: &GTable,
    dm: &DistanceMatrix,
    params: &OnlineParams,
) -> LightDecision {
    let nv = busy.len();
    let nl = resources.len();
    let max_y = gtable.max_parallelism().max(1);
    let delay = |m: usize, y: usize| -> f64 {
        if params.use_mean_delay {
            gtable.mean_delay(m, y)
        } else {
            gtable.delay(m, y)
        }
    };

    let mut x: Vec<Vec<u32>> = busy.to_vec();
    let mut residual: Vec<[f64; NUM_RESOURCES]> = residual.to_vec();
    let mut stats = GreedyStats::default();

    // Queue indices grouped by required MS, H-descending within a group
    // (urgent tasks claim capacity first).
    let mut by_ms: Vec<Vec<usize>> = vec![Vec::new(); nl];
    for (qi, r) in queue.iter().enumerate() {
        by_ms[r.light_idx].push(qi);
    }
    for group in &mut by_ms {
        group.sort_by(|&a, &b| queue[b].h.total_cmp(&queue[a].h));
    }

    let fits = |residual: &[[f64; NUM_RESOURCES]], v: usize, m: usize| -> bool {
        (0..NUM_RESOURCES).all(|k| residual[v][k] >= resources[m][k] - 1e-12)
    };

    // Current best next-hop latency per queued task under deployment `x`
    // (penalty when unroutable).
    let mut current: Vec<f64> = vec![params.unroutable_penalty_ms; queue.len()];
    let mut route_group = |m: usize,
                           x: &Vec<Vec<u32>>,
                           current: &mut Vec<f64>| {
        // Greedy sequential routing of group m, tracking per-node y.
        let mut y = vec![0u32; nv];
        for &qi in &by_ms[m] {
            let req = &queue[qi];
            let mut best = params.unroutable_penalty_ms;
            let mut best_v = usize::MAX;
            for v in 0..nv {
                if x[v][m] == 0 || y[v] >= group_capacity(x[v][m], max_y) {
                    continue;
                }
                let per_inst = ((y[v] + 1) as usize).div_ceil(x[v][m] as usize);
                let t = dm.latency(req.from_node, v, req.payload_mb) + delay(m, per_inst);
                if t < best {
                    best = t;
                    best_v = v;
                }
            }
            if best_v != usize::MAX {
                y[best_v] += 1;
            }
            current[qi] = best;
        }
    };
    for m in 0..nl {
        route_group(m, &x, &mut current);
    }

    // Marginal ΔL of adding one instance of m at v, scored with prefix
    // sums over the group's gains. Returns f64::INFINITY when worthless.
    let score_candidate = |v: usize,
                           m: usize,
                           current: &Vec<f64>,
                           pairs: &mut Vec<(f64, f64)>|
     -> f64 {
        let group = &by_ms[m];
        if group.is_empty() {
            return f64::INFINITY;
        }
        // gains_j = cur_j − net_j(v); only positive-potential tasks matter.
        pairs.clear();
        for &qi in group {
            let req = &queue[qi];
            let net = dm.latency(req.from_node, v, req.payload_mb);
            let gain = current[qi] - net;
            if gain > 0.0 {
                pairs.push((params.phi * req.h, gain));
            }
        }
        if pairs.is_empty() {
            return f64::INFINITY;
        }
        pairs.sort_by(|a, b| b.1.total_cmp(&a.1));
        let (c_dp, c_mt, c_pl) = costs[m];
        let mut best = f64::INFINITY;
        let mut w_sum = 0.0; // Σ φH over prefix
        let mut wg_sum = 0.0; // Σ φH·gain over prefix
        for (rank, &(w, g)) in pairs.iter().enumerate() {
            let yy = rank + 1;
            if yy > max_y {
                break;
            }
            w_sum += w;
            wg_sum += w * g;
            let g_y = delay(m, yy);
            // ΔL(y) = η·cost + Σ_{top y} φH·(g(y) − gain_j)
            let dl = params.eta * (c_dp + c_mt + c_pl * yy as f64) + g_y * w_sum - wg_sum;
            if dl < best {
                best = dl;
            }
        }
        best
    };

    // Initial candidate table.
    let mut delta = vec![vec![f64::INFINITY; nl]; nv];
    let mut scratch: Vec<(f64, f64)> = Vec::new();
    for m in 0..nl {
        if by_ms[m].is_empty() {
            continue;
        }
        for v in 0..nv {
            if fits(&residual, v, m) {
                stats.candidates_scanned += 1;
                delta[v][m] = score_candidate(v, m, &current, &mut scratch);
            }
        }
    }

    // Greedy loop: commit the most negative marginal, refresh only the
    // affected service's candidates (queue groups are disjoint).
    loop {
        if stats.iterations >= params.max_iterations {
            break;
        }
        let mut best: Option<(usize, usize, f64)> = None;
        for v in 0..nv {
            for m in 0..nl {
                let d = delta[v][m];
                if d < 0.0 && best.map_or(true, |(_, _, b)| d < b) {
                    best = Some((v, m, d));
                }
            }
        }
        let Some((v, m, _)) = best else { break };
        // Validate against current capacity (it may have been consumed).
        if !fits(&residual, v, m) {
            delta[v][m] = f64::INFINITY;
            continue;
        }
        x[v][m] += 1;
        for k in 0..NUM_RESOURCES {
            residual[v][k] -= resources[m][k];
        }
        stats.instances_added += 1;
        stats.iterations += 1;
        // Re-route group m and refresh its candidate column.
        route_group(m, &x, &mut current);
        for vv in 0..nv {
            delta[vv][m] = if fits(&residual, vv, m) {
                stats.candidates_scanned += 1;
                score_candidate(vv, m, &current, &mut scratch)
            } else {
                f64::INFINITY
            };
        }
        // Capacity at v shrank: invalidate other services' entries there
        // if they no longer fit (cheap check).
        for mm in 0..nl {
            if mm != m && delta[v][mm].is_finite() && !fits(&residual, v, mm) {
                delta[v][mm] = f64::INFINITY;
            }
        }
    }

    // Final routing pass against the committed deployment. Unlike the
    // marginal estimates above (which compare against the waiting
    // penalty), this pass always uses existing capacity: waiting another
    // slot never beats starting now under FCFS service.
    let mut y = vec![vec![0u32; nl]; nv];
    let mut assignments: Vec<Option<Assignment>> = vec![None; queue.len()];
    for group in &by_ms {
        for &qi in group {
            let req = &queue[qi];
            let m = req.light_idx;
            let mut best: Option<Assignment> = None;
            for v in 0..nv {
                if x[v][m] == 0 || y[v][m] >= group_capacity(x[v][m], max_y) {
                    continue;
                }
                let per_inst = ((y[v][m] + 1) as usize).div_ceil(x[v][m] as usize);
                let net = dm.latency(req.from_node, v, req.payload_mb);
                let est = delay(m, per_inst);
                let total = net + est;
                // Unreachable under the current fault state (infinite
                // routed latency): waiting beats routing into a void.
                if !total.is_finite() {
                    continue;
                }
                if best
                    .as_ref()
                    .map_or(true, |b| total < b.transfer_ms + b.est_proc_ms)
                {
                    best = Some(Assignment {
                        node: v,
                        light_idx: m,
                        y: per_inst as u32,
                        transfer_ms: net,
                        est_proc_ms: est,
                    });
                }
            }
            if let Some(a) = best {
                y[a.node][m] += 1;
                assignments[qi] = Some(a);
            }
        }
    }

    // Final objective (19) for diagnostics.
    let mut objective = 0.0;
    for v in 0..nv {
        for m in 0..nl {
            if x[v][m] > busy[v][m] {
                let (c_dp, c_mt, c_pl) = costs[m];
                objective += params.eta
                    * ((c_dp + c_mt) * (x[v][m] - busy[v][m]) as f64 + c_pl * y[v][m] as f64);
            }
        }
    }
    for (qi, a) in assignments.iter().enumerate() {
        let req = &queue[qi];
        let t = match a {
            Some(a) => a.transfer_ms + a.est_proc_ms,
            None => params.unroutable_penalty_ms,
        };
        objective += params.phi * req.h * t;
    }
    stats.objective = objective;

    LightDecision {
        x,
        y,
        assignments,
        stats,
    }
}
