//! Virtual deadline-violation queues (eq. 18).
//!
//! `H_j(t+1) = max{ H_j(t) + T_j(t) − D_n, ζ }` — the floor ζ > 0 keeps
//! the controller *proactively* latency-averse instead of reacting only
//! after violations accumulate (the paper's departure from vanilla
//! drift-plus-penalty).

use std::collections::BTreeMap;

/// Per-task virtual queues.
///
/// Backed by a `BTreeMap` so `total_backlog()` sums in task-id order:
/// float addition is not associative, and a hash-ordered sum leaked
/// per-process noise into the telemetry stream.
#[derive(Clone, Debug)]
pub struct VirtualQueues {
    h: BTreeMap<u64, f64>,
    zeta: f64,
}

impl VirtualQueues {
    pub fn new(zeta: f64) -> Self {
        assert!(zeta >= 0.0);
        VirtualQueues {
            h: BTreeMap::new(),
            zeta,
        }
    }

    /// Current queue value; tasks not yet tracked sit at the floor ζ.
    pub fn value(&self, task_id: u64) -> f64 {
        *self.h.get(&task_id).unwrap_or(&self.zeta)
    }

    /// Slot update (eq. 18): `T_j(t)` is the latency the task has
    /// experienced under decisions made by time `t`.
    pub fn update(&mut self, task_id: u64, experienced_ms: f64, deadline_ms: f64) {
        let cur = self.value(task_id);
        let next = (cur + experienced_ms - deadline_ms).max(self.zeta);
        self.h.insert(task_id, next);
    }

    /// Forget a finished/dropped task.
    pub fn remove(&mut self, task_id: u64) {
        self.h.remove(&task_id);
    }

    /// Number of tracked tasks.
    pub fn len(&self) -> usize {
        self.h.len()
    }

    pub fn is_empty(&self) -> bool {
        self.h.is_empty()
    }

    /// Sum of all queue values (Lyapunov function diagnostic).
    pub fn total_backlog(&self) -> f64 {
        self.h.values().sum()
    }

    pub fn zeta(&self) -> f64 {
        self.zeta
    }
}
