//! Dynamic light-microservice deployment (§III-B): Lyapunov virtual
//! queues with a proactive floor (eq. 18), the drift-plus-penalty
//! objective (eq. 19), and the low-complexity greedy online Algorithm 1
//! driven by the effective-capacity map `g_{m,ε}(y)`.

mod greedy;
mod lyapunov;

pub use greedy::{greedy_light_deployment, Assignment, GreedyStats, LightDecision, LightRequest};
pub use lyapunov::VirtualQueues;

/// Per-slot controller configuration shared by the proposal and PropAvg.
#[derive(Clone, Debug)]
pub struct OnlineParams {
    /// Cost weight η of (19).
    pub eta: f64,
    /// Priority weight φ (uniform).
    pub phi: f64,
    /// Use the mean-value delay column instead of `g_{m,ε}` (PropAvg).
    pub use_mean_delay: bool,
    /// Penalty latency (ms) for a task that cannot be routed this slot.
    pub unroutable_penalty_ms: f64,
    /// Hard cap on greedy iterations per slot (safety net; `M` in the
    /// complexity bound).
    pub max_iterations: usize,
}

impl OnlineParams {
    pub fn from_config(c: &crate::config::ControllerConfig) -> Self {
        OnlineParams {
            eta: c.eta,
            phi: c.phi,
            use_mean_delay: false,
            unroutable_penalty_ms: 200.0,
            max_iterations: 256,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, NUM_RESOURCES};
    use crate::effcap::{GTable, GTableParams};
    use crate::microservice::build_fig1_application;
    use crate::network::Topology;
    use crate::rng::{Distribution, Gamma, Xoshiro256};
    use crate::routing::DistanceMatrix;

    #[test]
    fn virtual_queue_floor_and_growth() {
        let mut q = VirtualQueues::new(0.5);
        // New task starts at the floor.
        assert_eq!(q.value(7), 0.5);
        // Early in its life (elapsed << deadline) the queue stays floored.
        q.update(7, 10.0, 80.0);
        assert_eq!(q.value(7), 0.5);
        // Past the deadline the backlog accumulates.
        q.update(7, 90.0, 80.0);
        assert!((q.value(7) - (0.5 + 10.0)).abs() < 1e-12);
        q.update(7, 100.0, 80.0);
        assert!((q.value(7) - (10.5 + 20.0)).abs() < 1e-12);
        q.remove(7);
        assert_eq!(q.value(7), 0.5);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn queue_never_drops_below_floor() {
        let mut q = VirtualQueues::new(2.0);
        q.update(1, 0.0, 1000.0); // huge slack
        assert_eq!(q.value(1), 2.0);
    }

    fn test_env() -> (
        crate::microservice::Application,
        Topology,
        DistanceMatrix,
        GTable,
        Vec<[f64; NUM_RESOURCES]>,
    ) {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(77);
        let app = build_fig1_application(&cfg, &mut rng);
        let topo = Topology::generate(&cfg, &mut rng);
        let dm = DistanceMatrix::build(&topo, 1.0);
        // g-table from the catalog's light services.
        let mut samples = Vec::new();
        let mut workloads = Vec::new();
        for &m in app.catalog.light_ids() {
            let spec = app.catalog.spec(m);
            samples.push(spec.rate.sample_n(&mut rng, 2048));
            workloads.push(spec.workload_mb);
        }
        let gt = GTable::build(&samples, &workloads, &GTableParams::default_paper());
        let residual: Vec<[f64; NUM_RESOURCES]> =
            topo.nodes().iter().map(|n| n.capacity).collect();
        (app, topo, dm, gt, residual)
    }

    fn mk_request(task: u64, light: usize, node: usize, h: f64) -> LightRequest {
        LightRequest {
            task_id: task,
            light_idx: light,
            from_node: node,
            payload_mb: 0.5,
            h,
            deadline_slack_ms: 40.0,
        }
    }

    #[test]
    fn empty_queue_deploys_nothing() {
        let (app, topo, dm, gt, residual) = test_env();
        let nl = app.catalog.num_light();
        let busy = vec![vec![0u32; nl]; topo.num_nodes()];
        let costs = light_costs(&app);
        let d = greedy_light_deployment(
            &[],
            &busy,
            &residual,
            &light_resources(&app),
            &costs,
            &gt,
            &dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        assert_eq!(d.assignments.len(), 0);
        assert_eq!(
            d.x.iter().flat_map(|r| r.iter()).sum::<u32>(),
            0,
            "no demand, no instances"
        );
    }

    fn light_costs(app: &crate::microservice::Application) -> Vec<(f64, f64, f64)> {
        app.catalog
            .light_ids()
            .iter()
            .map(|&m| {
                let s = app.catalog.spec(m);
                (s.cost_deploy, s.cost_maint, s.cost_parallel)
            })
            .collect()
    }

    fn light_resources(
        app: &crate::microservice::Application,
    ) -> Vec<[f64; NUM_RESOURCES]> {
        app.catalog
            .light_ids()
            .iter()
            .map(|&m| app.catalog.spec(m).resources)
            .collect()
    }

    #[test]
    fn queued_tasks_get_assigned_when_capacity_allows() {
        let (app, topo, dm, gt, residual) = test_env();
        let nl = app.catalog.num_light();
        let busy = vec![vec![0u32; nl]; topo.num_nodes()];
        let reqs: Vec<LightRequest> = (0..6).map(|i| mk_request(i, 0, 0, 5.0)).collect();
        let d = greedy_light_deployment(
            &reqs,
            &busy,
            &residual,
            &light_resources(&app),
            &light_costs(&app),
            &gt,
            &dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        let assigned = d.assignments.iter().filter(|a| a.is_some()).count();
        assert!(assigned == 6, "all tasks should be served, got {assigned}");
        // Instances actually deployed for light MS 0 somewhere.
        let total: u32 = d.x.iter().map(|r| r[0]).sum();
        assert!(total >= 1);
        // Parallelism counts match assignments.
        let y_total: u32 = d.y.iter().map(|r| r[0]).sum();
        assert_eq!(y_total as usize, assigned);
    }

    #[test]
    fn no_capacity_means_no_assignment() {
        let (app, topo, dm, gt, _) = test_env();
        let nl = app.catalog.num_light();
        let busy = vec![vec![0u32; nl]; topo.num_nodes()];
        let zero = vec![[0.0; NUM_RESOURCES]; topo.num_nodes()];
        let reqs = vec![mk_request(0, 2, 0, 5.0)];
        let d = greedy_light_deployment(
            &reqs,
            &busy,
            &zero,
            &light_resources(&app),
            &light_costs(&app),
            &gt,
            &dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        assert!(d.assignments[0].is_none());
    }

    #[test]
    fn busy_instances_are_reused_without_new_cost() {
        let (app, topo, dm, gt, residual) = test_env();
        let nl = app.catalog.num_light();
        let mut busy = vec![vec![0u32; nl]; topo.num_nodes()];
        busy[0][1] = 1; // existing instance of light MS 1 at node 0
        let reqs = vec![mk_request(0, 1, 0, 5.0)];
        let d = greedy_light_deployment(
            &reqs,
            &busy,
            &residual,
            &light_resources(&app),
            &light_costs(&app),
            &gt,
            &dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        let a = d.assignments[0].expect("task served by the busy instance");
        assert_eq!((a.node, a.light_idx), (0, 1));
        assert_eq!(d.stats.instances_added, 0, "no new instance needed");
    }

    #[test]
    fn urgent_tasks_win_contended_capacity() {
        let (app, topo, dm, gt, _) = test_env();
        let nl = app.catalog.num_light();
        let busy = vec![vec![0u32; nl]; topo.num_nodes()];
        // Capacity fits exactly one instance of light MS 0 at node 0 only.
        let mut tight = vec![[0.0; NUM_RESOURCES]; topo.num_nodes()];
        let res0 = light_resources(&app)[0];
        tight[0] = res0;
        let mut reqs = vec![
            mk_request(0, 0, 0, 1.0),   // low urgency
            mk_request(1, 0, 0, 100.0), // high urgency
        ];
        // One parallel slot only: cap y by building a tiny gtable? Instead
        // rely on ordering: assignments are made highest-H first.
        let d = greedy_light_deployment(
            &reqs,
            &busy,
            &tight,
            &light_resources(&app),
            &light_costs(&app),
            &gt,
            &dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        // Both may share the instance via parallelism, but the urgent one
        // must be served.
        assert!(d.assignments[1].is_some());
        reqs.clear();
    }

    #[test]
    fn decision_respects_resource_budget() {
        let (app, topo, dm, gt, residual) = test_env();
        let nl = app.catalog.num_light();
        let busy = vec![vec![0u32; nl]; topo.num_nodes()];
        let reqs: Vec<LightRequest> = (0..40)
            .map(|i| mk_request(i, (i % 3) as usize, (i % 12) as usize, 3.0))
            .collect();
        let resources = light_resources(&app);
        let d = greedy_light_deployment(
            &reqs,
            &busy,
            &residual,
            &resources,
            &light_costs(&app),
            &gt,
            &dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        for (v, row) in d.x.iter().enumerate() {
            for k in 0..NUM_RESOURCES {
                let used: f64 = row
                    .iter()
                    .enumerate()
                    .map(|(mi, &c)| resources[mi][k] * c as f64)
                    .sum();
                assert!(
                    used <= residual[v][k] + 1e-9,
                    "node {v} resource {k}: {used} > {}",
                    residual[v][k]
                );
            }
        }
    }

    #[test]
    fn propavg_mode_uses_smaller_delays() {
        // Mean delays are <= QoS bounds, so PropAvg should estimate lower
        // latency for the same decision.
        let g = Gamma::new(1.5, 8.0);
        let mut rng = Xoshiro256::seed_from(3);
        let samples = g.sample_n(&mut rng, 4096);
        let gt = GTable::build(&[samples], &[1.0], &GTableParams::default_paper());
        for y in 1..=16 {
            assert!(gt.mean_delay(0, y) <= gt.delay(0, y) + 1e-12);
        }
    }
}
