//! System-cost accounting (eqs. 6–7).
//!
//! Core cost: one-time deployment + per-slot maintenance per instance.
//! Light cost: instantiation on each *increase* of the instance count,
//! per-slot maintenance, and per-slot parallelism cost.

/// Cost breakdown over one horizon.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    pub core_deploy: f64,
    pub core_maintain: f64,
    pub light_instantiate: f64,
    pub light_maintain: f64,
    pub light_parallel: f64,
}

impl CostBreakdown {
    pub fn core_total(&self) -> f64 {
        self.core_deploy + self.core_maintain
    }

    pub fn light_total(&self) -> f64 {
        self.light_instantiate + self.light_maintain + self.light_parallel
    }

    pub fn total(&self) -> f64 {
        self.core_total() + self.light_total()
    }
}

/// Streaming cost accumulator: the simulator calls it once per slot.
#[derive(Clone, Debug, Default)]
pub struct CostBook {
    b: CostBreakdown,
    /// Previous slot's light instance counts, `[node][light_idx]`.
    prev_light: Vec<Vec<u32>>,
}

impl CostBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge the static core placement (eq. 6): deployment once plus
    /// maintenance for `slots` slots for every instance.
    pub fn charge_core_placement(
        &mut self,
        instances: &[Vec<u32>],
        cost_deploy: &[f64],
        cost_maint: &[f64],
        slots: usize,
    ) {
        for row in instances {
            for (m, &x) in row.iter().enumerate() {
                let x = x as f64;
                self.b.core_deploy += cost_deploy[m] * x;
                self.b.core_maintain += cost_maint[m] * x * slots as f64;
            }
        }
    }

    /// Charge one slot of light deployment (eq. 7).
    ///
    /// * `x[v][m]` — instance counts this slot.
    /// * `y[v][m]` — total parallelism (concurrent tasks) this slot.
    pub fn charge_light_slot(
        &mut self,
        x: &[Vec<u32>],
        y: &[Vec<u32>],
        cost_inst: &[f64],
        cost_maint: &[f64],
        cost_par: &[f64],
    ) {
        if self.prev_light.is_empty() {
            self.prev_light = vec![vec![0; x.first().map_or(0, Vec::len)]; x.len()];
        }
        for (v, row) in x.iter().enumerate() {
            for (m, &count) in row.iter().enumerate() {
                let prev = self.prev_light[v][m];
                if count > prev {
                    self.b.light_instantiate += cost_inst[m] * (count - prev) as f64;
                }
                self.b.light_maintain += cost_maint[m] * count as f64;
                self.b.light_parallel += cost_par[m] * y[v][m] as f64;
            }
        }
        self.prev_light = x.to_vec();
    }

    pub fn breakdown(&self) -> CostBreakdown {
        self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_cost_eq6() {
        let mut book = CostBook::new();
        // 2 instances of MS0 on node0, 1 of MS1 on node1; 10 slots.
        let placement = vec![vec![2, 0], vec![0, 1]];
        book.charge_core_placement(&placement, &[20.0, 20.0], &[4.0, 4.0], 10);
        let b = book.breakdown();
        assert_eq!(b.core_deploy, 60.0); // 3 * 20
        assert_eq!(b.core_maintain, 120.0); // 3 * 4 * 10
        assert_eq!(b.total(), 180.0);
    }

    #[test]
    fn light_instantiation_charged_on_increase_only() {
        let mut book = CostBook::new();
        let inst = [4.0];
        let maint = [1.0];
        let par = [0.5];
        // slot 1: 2 instances, parallelism 3
        book.charge_light_slot(&[vec![2]], &[vec![3]], &inst, &maint, &par);
        // slot 2: down to 1 instance (no instantiation cost)
        book.charge_light_slot(&[vec![1]], &[vec![1]], &inst, &maint, &par);
        // slot 3: back to 3 instances (2 new instantiations)
        book.charge_light_slot(&[vec![3]], &[vec![4]], &inst, &maint, &par);
        let b = book.breakdown();
        assert_eq!(b.light_instantiate, 4.0 * (2 + 0 + 2) as f64);
        assert_eq!(b.light_maintain, 1.0 * (2 + 1 + 3) as f64);
        assert_eq!(b.light_parallel, 0.5 * (3 + 1 + 4) as f64);
    }

    #[test]
    fn zero_activity_costs_nothing() {
        let mut book = CostBook::new();
        book.charge_light_slot(&[vec![0, 0]], &[vec![0, 0]], &[4.0, 4.0], &[1.0, 1.0], &[0.5, 0.5]);
        assert_eq!(book.breakdown().total(), 0.0);
    }
}
