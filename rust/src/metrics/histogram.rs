//! Fixed-bin histograms for streaming latency / queue-depth observation.
//!
//! The DES engine records one sojourn sample per light-service execution
//! and one queue-depth sample per controller tick; a trial can easily
//! produce 10^5+ of each, so the collector keeps O(bins) state with exact
//! count/sum and approximate quantiles (linear interpolation inside the
//! owning bin).

/// Bin-edge layout.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Scale {
    Linear,
    Log,
}

/// A fixed-bin histogram over `[lo, hi)` with under/overflow buckets.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    scale: Scale,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    /// Empty single-bin placeholder — for trials that collect no service
    /// observations (e.g. the slotted engine).
    fn default() -> Self {
        Histogram::linear(0.0, 1.0, 1)
    }
}

impl Histogram {
    /// Linearly spaced bins over `[lo, hi)`.
    pub fn linear(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0, "need hi > lo and at least one bin");
        Histogram {
            lo,
            hi,
            scale: Scale::Linear,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Log-spaced bins over `[lo, hi)` (`lo > 0`) — the latency default:
    /// constant relative resolution from sub-ms to the deadline scale.
    pub fn log(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && bins > 0, "log bins need 0 < lo < hi");
        Histogram {
            lo,
            hi,
            scale: Scale::Log,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Latency default: 64 log bins from 10 µs to 10 s.
    pub fn latency_ms() -> Self {
        Histogram::log(1e-2, 1e4, 64)
    }

    fn bin_of(&self, x: f64) -> Option<usize> {
        if x < self.lo {
            return None;
        }
        let n = self.counts.len() as f64;
        let frac = match self.scale {
            Scale::Linear => (x - self.lo) / (self.hi - self.lo),
            Scale::Log => (x / self.lo).ln() / (self.hi / self.lo).ln(),
        };
        let i = (frac * n).floor() as usize;
        if i >= self.counts.len() {
            None
        } else {
            Some(i)
        }
    }

    /// Lower edge of bin `i`.
    fn edge(&self, i: usize) -> f64 {
        let frac = i as f64 / self.counts.len() as f64;
        match self.scale {
            Scale::Linear => self.lo + frac * (self.hi - self.lo),
            Scale::Log => self.lo * (self.hi / self.lo).powf(frac),
        }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x < self.lo {
            self.underflow += 1;
        } else {
            match self.bin_of(x) {
                Some(i) => self.counts[i] += 1,
                None => self.overflow += 1,
            }
        }
    }

    /// Merge another histogram with identical layout.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.lo, other.lo);
        assert_eq!(self.hi, other.hi);
        assert_eq!(self.scale, other.scale);
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate p-quantile (p in [0,1]): linear interpolation within
    /// the bin holding the target rank. Under/overflow resolve to the
    /// recorded min/max. `None` when the histogram is empty — a rank
    /// target of at least one observation is meaningless at zero count.
    pub fn quantile(&self, p: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let target = (p.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = self.underflow;
        if target <= seen {
            return Some(self.min());
        }
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if target <= seen + c {
                let lo_edge = self.edge(i);
                let hi_edge = self.edge(i + 1);
                let within = (target - seen) as f64 / c as f64;
                return Some(lo_edge + within * (hi_edge - lo_edge));
            }
            seen += c;
        }
        Some(self.max())
    }

    /// Empirical complementary CDF at `t`: fraction of observations
    /// strictly greater than `t`, resolved at bin granularity (samples in
    /// the bin containing `t` count partially via linear interpolation).
    pub fn ccdf(&self, t: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if t < self.lo {
            return (self.count - self.underflow) as f64 / self.count as f64;
        }
        let mut above = self.overflow;
        if let Some(bt) = self.bin_of(t) {
            for (i, &c) in self.counts.iter().enumerate() {
                if i > bt {
                    above += c;
                } else if i == bt {
                    let lo_edge = self.edge(i);
                    let hi_edge = self.edge(i + 1);
                    let frac_above = ((hi_edge - t) / (hi_edge - lo_edge)).clamp(0.0, 1.0);
                    above += (c as f64 * frac_above).round() as u64;
                }
            }
        }
        above as f64 / self.count as f64
    }

    /// One-line summary for reports.
    pub fn row(&self) -> String {
        let q = |p: f64| self.quantile(p).unwrap_or(0.0);
        format!(
            "n={} mean={:.2} p50={:.2} p95={:.2} p99={:.2} max={:.2}",
            self.count,
            self.mean(),
            q(0.5),
            q(0.95),
            q(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_bins_count_and_mean() {
        let mut h = Histogram::linear(0.0, 10.0, 10);
        for i in 0..10 {
            h.record(i as f64 + 0.5);
        }
        assert_eq!(h.count(), 10);
        assert!((h.mean() - 5.0).abs() < 1e-12);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 9.5);
    }

    #[test]
    fn under_and_overflow_tracked() {
        let mut h = Histogram::linear(1.0, 2.0, 4);
        h.record(0.5);
        h.record(1.5);
        h.record(5.0);
        assert_eq!(h.count(), 3);
        // quantiles resolve to recorded extremes at the tails
        assert_eq!(h.quantile(0.0), Some(0.5));
        assert_eq!(h.quantile(1.0), Some(5.0));
    }

    #[test]
    fn quantiles_are_ordered_and_bracketed() {
        let mut h = Histogram::log(0.1, 1000.0, 48);
        for i in 1..=1000 {
            h.record(i as f64 * 0.1);
        }
        let p50 = h.quantile(0.5).expect("non-empty");
        let p95 = h.quantile(0.95).expect("non-empty");
        let p99 = h.quantile(0.99).expect("non-empty");
        assert!(p50 <= p95 && p95 <= p99);
        assert!((p50 - 50.0).abs() < 5.0, "p50≈50, got {p50}");
        assert!((p95 - 95.0).abs() < 8.0, "p95≈95, got {p95}");
    }

    #[test]
    fn ccdf_decreases() {
        let mut h = Histogram::log(0.1, 100.0, 32);
        for i in 1..=100 {
            h.record(i as f64);
        }
        let a = h.ccdf(10.0);
        let b = h.ccdf(50.0);
        let c = h.ccdf(90.0);
        assert!(a > b && b > c);
        assert!((a - 0.9).abs() < 0.05, "ccdf(10)≈0.9, got {a}");
        assert_eq!(h.ccdf(1e9), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Histogram::linear(0.0, 10.0, 5);
        let mut b = Histogram::linear(0.0, 10.0, 5);
        a.record(1.0);
        b.record(9.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 9.0);
        assert!((a.mean() - 5.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_bounds() {
        // Silently merging histograms with different bin layouts would
        // corrupt quantiles — mismatches must refuse loudly.
        let mut a = Histogram::linear(0.0, 10.0, 5);
        let b = Histogram::linear(0.0, 20.0, 5);
        a.merge(&b);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_scale() {
        let mut a = Histogram::linear(1.0, 10.0, 5);
        let b = Histogram::log(1.0, 10.0, 5);
        a.merge(&b);
    }

    #[test]
    fn empty_histogram_is_benign() {
        let h = Histogram::default();
        assert!(h.is_empty());
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.ccdf(1.0), 0.0);
        // row() must not panic on an empty histogram.
        assert!(h.row().contains("n=0"));
    }

    #[test]
    fn empty_histogram_quantiles_are_none() {
        // Regression: the rank target used to be forced to >= 1 even at
        // zero count, which made empty-histogram quantiles meaningless.
        let h = Histogram::log(0.1, 100.0, 16);
        for p in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(p), None, "p={p} on an empty histogram");
        }
        // One observation: every quantile resolves to it.
        let mut h = Histogram::log(0.1, 100.0, 16);
        h.record(7.0);
        for p in [0.0, 0.5, 1.0] {
            let q = h.quantile(p).expect("single-sample quantile");
            assert!(q > 0.0 && q.is_finite());
        }
    }
}
