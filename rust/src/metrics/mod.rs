//! Metrics: the paper's evaluation quantities — on-time task completion
//! rate, total system cost (eqs. 6–7) — plus the distribution machinery
//! behind Fig. 3's violins (quantiles, kernel density estimates) and
//! Fig. 4's error bars (mean ± std over trials).

mod cost;
mod stats;

pub use cost::{CostBook, CostBreakdown};
pub use stats::{kde_violin, quantile, Summary, ViolinData};

/// Outcome of one completed (or dropped) task.
#[derive(Clone, Copy, Debug)]
pub struct TaskOutcome {
    pub task_id: u64,
    /// End-to-end latency (ms); `None` if never completed in-horizon.
    pub latency_ms: Option<f64>,
    pub deadline_ms: f64,
}

impl TaskOutcome {
    pub fn completed(&self) -> bool {
        self.latency_ms.is_some()
    }

    pub fn on_time(&self) -> bool {
        self.latency_ms.map_or(false, |l| l <= self.deadline_ms)
    }
}

/// Aggregated metrics of one simulation trial.
#[derive(Clone, Debug, Default)]
pub struct TrialMetrics {
    pub total_tasks: usize,
    pub completed: usize,
    pub on_time: usize,
    pub total_cost: f64,
    pub core_cost: f64,
    pub light_cost: f64,
    /// Completed-task latencies (ms).
    pub latencies_ms: Vec<f64>,
    /// Deadlines of all admitted tasks (for slack analysis).
    pub mean_deadline_ms: f64,
}

impl TrialMetrics {
    /// Fraction of admitted tasks completed within the horizon.
    pub fn completion_rate(&self) -> f64 {
        if self.total_tasks == 0 {
            return 1.0;
        }
        self.completed as f64 / self.total_tasks as f64
    }

    /// Fraction of admitted tasks completed before their deadline — the
    /// paper's headline metric (>84% for the proposal).
    pub fn on_time_rate(&self) -> f64 {
        if self.total_tasks == 0 {
            return 1.0;
        }
        self.on_time as f64 / self.total_tasks as f64
    }

    /// Latency percentile over completed tasks.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        quantile(&v, p)
    }
}

/// Accumulates outcomes during a trial.
#[derive(Clone, Debug, Default)]
pub struct MetricsCollector {
    outcomes: Vec<TaskOutcome>,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, o: TaskOutcome) {
        self.outcomes.push(o);
    }

    pub fn len(&self) -> usize {
        self.outcomes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.outcomes.is_empty()
    }

    /// Fold into trial metrics, attaching the cost book's totals.
    pub fn finish(self, costs: &CostBook) -> TrialMetrics {
        let total_tasks = self.outcomes.len();
        let completed = self.outcomes.iter().filter(|o| o.completed()).count();
        let on_time = self.outcomes.iter().filter(|o| o.on_time()).count();
        let latencies_ms: Vec<f64> = self
            .outcomes
            .iter()
            .filter_map(|o| o.latency_ms)
            .collect();
        let mean_deadline_ms = if total_tasks > 0 {
            self.outcomes.iter().map(|o| o.deadline_ms).sum::<f64>() / total_tasks as f64
        } else {
            0.0
        };
        let b = costs.breakdown();
        TrialMetrics {
            total_tasks,
            completed,
            on_time,
            total_cost: b.total(),
            core_cost: b.core_total(),
            light_cost: b.light_total(),
            latencies_ms,
            mean_deadline_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(lat: Option<f64>, dl: f64) -> TaskOutcome {
        TaskOutcome {
            task_id: 0,
            latency_ms: lat,
            deadline_ms: dl,
        }
    }

    #[test]
    fn rates_computed_correctly() {
        let mut c = MetricsCollector::new();
        c.record(outcome(Some(10.0), 20.0)); // on time
        c.record(outcome(Some(30.0), 20.0)); // late
        c.record(outcome(None, 20.0)); // dropped
        let m = c.finish(&CostBook::default());
        assert_eq!(m.total_tasks, 3);
        assert_eq!(m.completed, 2);
        assert_eq!(m.on_time, 1);
        assert!((m.completion_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.on_time_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_trial_has_unit_rates() {
        let m = MetricsCollector::new().finish(&CostBook::default());
        assert_eq!(m.completion_rate(), 1.0);
        assert_eq!(m.on_time_rate(), 1.0);
    }

    #[test]
    fn deadline_boundary_counts_on_time() {
        let o = outcome(Some(20.0), 20.0);
        assert!(o.on_time());
        let o2 = outcome(Some(20.000001), 20.0);
        assert!(!o2.on_time());
    }

    #[test]
    fn latency_percentiles() {
        let mut c = MetricsCollector::new();
        for i in 1..=100 {
            c.record(outcome(Some(i as f64), 1000.0));
        }
        let m = c.finish(&CostBook::default());
        assert!((m.latency_percentile(0.5) - 50.5).abs() < 1.0);
        assert!(m.latency_percentile(0.99) >= 99.0);
    }
}
