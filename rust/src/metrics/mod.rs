//! Metrics: the paper's evaluation quantities — on-time task completion
//! rate, total system cost (eqs. 6–7) — plus the distribution machinery
//! behind Fig. 3's violins (quantiles, kernel density estimates) and
//! Fig. 4's error bars (mean ± std over trials).

mod cost;
mod histogram;
mod stats;

pub use cost::{CostBook, CostBreakdown};
pub use histogram::Histogram;
pub use stats::{kde_violin, quantile, Summary, ViolinData};

/// Per-light-service sojourn observations: what a task actually
/// experienced at its assigned replica (queue wait + service), the
/// measured counterpart of the analytic bound `g_{m,ε}(y)`. Populated by
/// the DES engine; the slotted engine leaves these empty.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceObs {
    /// Sojourn-time distribution (ms). Recorded in both retained and
    /// streaming modes, so count/mean/max/quantiles are always available.
    pub sojourn: Histogram,
    /// Raw `(decision parallelism y, sojourn ms)` pairs — the bound
    /// validation compares each sample against `g_{m,ε}(y)` at its own y.
    /// Empty in streaming mode (the comparison happens at record time).
    pub samples: Vec<(u32, f64)>,
    /// Executions whose sojourn exceeded the analytic bound at their
    /// committed y. Maintained by [`Self::record_streamed`] only; with
    /// retained samples `des::validate` recomputes it from `samples`.
    pub violations: u64,
    /// Sum of the per-execution bounds `g_{m,ε}(y)` seen by
    /// [`Self::record_streamed`] (for the mean-bound column of the
    /// validation report without retained samples).
    pub sum_bound_ms: f64,
}

impl ServiceObs {
    /// Fresh observation set with the latency-scaled histogram.
    pub fn new() -> Self {
        ServiceObs {
            sojourn: Histogram::latency_ms(),
            samples: Vec::new(),
            violations: 0,
            sum_bound_ms: 0.0,
        }
    }

    pub fn record(&mut self, y: u32, sojourn_ms: f64) {
        self.sojourn.record(sojourn_ms);
        self.samples.push((y, sojourn_ms));
    }

    /// Streaming-mode record: the bound comparison happens now, against
    /// the `g_{m,ε}(y)` value the caller looked up for this execution's
    /// y, and only aggregates are retained.
    pub fn record_streamed(&mut self, sojourn_ms: f64, bound_ms: f64) {
        self.sojourn.record(sojourn_ms);
        if sojourn_ms > bound_ms {
            self.violations += 1;
        }
        self.sum_bound_ms += bound_ms;
    }
}

/// Outcome of one completed (or dropped) task.
#[derive(Clone, Copy, Debug)]
pub struct TaskOutcome {
    pub task_id: u64,
    /// End-to-end latency (ms); `None` if never completed in-horizon.
    pub latency_ms: Option<f64>,
    pub deadline_ms: f64,
}

impl TaskOutcome {
    pub fn completed(&self) -> bool {
        self.latency_ms.is_some()
    }

    pub fn on_time(&self) -> bool {
        self.latency_ms.map_or(false, |l| l <= self.deadline_ms)
    }
}

/// Aggregated metrics of one simulation trial.
///
/// `PartialEq` exists for the zero-overhead observability gate: a traced
/// run must produce metrics equal to the untraced run on the same seed.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TrialMetrics {
    pub total_tasks: usize,
    pub completed: usize,
    pub on_time: usize,
    pub total_cost: f64,
    pub core_cost: f64,
    pub light_cost: f64,
    /// Completed-task latencies (ms), sorted ascending — [`MetricsCollector::finish`]
    /// sorts once so percentile queries are allocation-free.
    pub latencies_ms: Vec<f64>,
    /// Deadlines of all admitted tasks (for slack analysis).
    pub mean_deadline_ms: f64,
    /// Per-light-service sojourn observations (DES engine; empty under
    /// the slotted engine).
    pub service_obs: Vec<ServiceObs>,
    /// Pending-work depth (controller queue + station FIFOs), sampled per
    /// controller tick (DES engine; empty under the slotted engine).
    pub queue_depth: Histogram,
    /// End-to-end latency distribution of completed tasks. Filled by
    /// [`MetricsCollector::finish`] in both modes; in streaming mode it
    /// is the only latency record (`latencies_ms` stays empty) and
    /// percentile queries resolve against it.
    pub latency_hist: Histogram,
    /// Calendar events processed by the DES engine (0 for slotted
    /// trials) — the numerator of the events/sec throughput figure.
    pub des_events: u64,
    /// Virtual-queue entries still tracked after the end-of-horizon drain.
    /// Every admitted task — finished, dropped, or faulted — must have
    /// been `remove()`d from [`crate::controller::VirtualQueues`] by then,
    /// so anything nonzero is a controller-state leak.
    pub vq_residual: usize,
    /// Tasks dropped because a fault destroyed state they could not
    /// recover from (an input payload lost with its node). Zero without
    /// fault injection. Recoverable casualties are *not* counted here —
    /// see [`Self::reroute_recovered`].
    pub fault_drops: usize,
    /// Stage executions cancelled by a fault and successfully
    /// re-dispatched to a surviving replica (including hedge
    /// promotions). The recoverable counterpart of `fault_drops`.
    pub reroute_recovered: usize,
    /// Fault-triggered retry cycles entered (each backoff wait counts
    /// once; a stage cancelled twice counts twice).
    pub retries: usize,
    /// Hedged standby executions booked near the deadline.
    pub hedges: usize,
    /// Core replicas brought back through checkpoint/restart.
    pub checkpoint_restores: usize,
    /// Light replicas spun up by the elastic pool tier (§P10), each one
    /// serving nothing for its seeded cold-start window. Zero when the
    /// pool is off.
    pub cold_starts: u64,
    /// Pool scaling decisions applied (grow or shrink, scale-to-zero
    /// included). Zero when the pool is off.
    pub pool_scale_events: u64,
    /// Scale-to-zero events: an idle station's entire pool drained away.
    pub pool_scale_to_zero: u64,
    /// Deployment-cost accounting for the elastic tier: total
    /// replica-slot-seconds provisioned (warm + warming) across every
    /// station, the denominator-free analogue of `light_cost`.
    pub pool_replica_slot_seconds: f64,
    /// Distribution of the fleet-wide pool size (warm replicas) sampled
    /// once per slot/tick. Default-empty when the pool is off.
    pub pool_size: Histogram,
}

impl TrialMetrics {
    /// Fraction of admitted tasks completed within the horizon.
    pub fn completion_rate(&self) -> f64 {
        if self.total_tasks == 0 {
            return 1.0;
        }
        self.completed as f64 / self.total_tasks as f64
    }

    /// Fraction of admitted tasks completed before their deadline — the
    /// paper's headline metric (>84% for the proposal).
    pub fn on_time_rate(&self) -> f64 {
        if self.total_tasks == 0 {
            return 1.0;
        }
        self.on_time as f64 / self.total_tasks as f64
    }

    /// Latency percentile over completed tasks; `0.0` for an empty trial
    /// (previously this fed an empty slice to [`quantile`] and returned
    /// NaN). [`MetricsCollector::finish`] stores the latencies sorted, so
    /// the common path neither allocates nor re-sorts; a hand-assembled
    /// unsorted vec falls back to one defensive copy.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            // Streaming trials keep no raw latencies; answer from the
            // histogram (approximate within its owning bin). An empty
            // histogram — a genuinely hollow trial — stays 0.0.
            return self.latency_hist.quantile(p).unwrap_or(0.0);
        }
        if self.latencies_ms.windows(2).all(|w| w[0] <= w[1]) {
            return quantile(&self.latencies_ms, p);
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(f64::total_cmp);
        quantile(&v, p)
    }
}

/// Accumulates outcomes during a trial.
///
/// Two storage modes. **Retained** (default): every outcome and sojourn
/// sample is kept, `finish` folds them — bit-identical to historical
/// behavior. **Streaming** ([`Self::enable_streaming`]): per-completion
/// counter/histogram accumulation with nothing retained per task, so
/// collector memory is O(bins) regardless of how many million tasks a
/// trial admits.
#[derive(Clone, Debug, Default)]
pub struct MetricsCollector {
    outcomes: Vec<TaskOutcome>,
    service_obs: Vec<ServiceObs>,
    queue_depth: Histogram,
    fault_drops: usize,
    reroute_recovered: usize,
    retries: usize,
    hedges: usize,
    checkpoint_restores: usize,
    streaming: bool,
    /// `bounds[light_idx][y]` = `g_{m,ε}(y)` snapshot for streaming-mode
    /// violation counting (y = 0 row mirrors y = 1, matching
    /// `GTable::delay`'s clamp).
    bounds: Vec<Vec<f64>>,
    total_tasks: usize,
    completed: usize,
    on_time: usize,
    sum_deadline_ms: f64,
    latency_hist: Histogram,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Turn on per-light-service sojourn + queue-depth collection (the
    /// DES engine calls this once; the slotted engine never does).
    pub fn enable_service_obs(&mut self, num_light: usize) {
        self.service_obs = (0..num_light).map(|_| ServiceObs::new()).collect();
        self.queue_depth = Histogram::linear(0.0, 512.0, 128);
    }

    /// Switch to streaming accumulation. `bounds[light_idx][y]` supplies
    /// the analytic sojourn bound each execution is checked against at
    /// record time (indexes past the row end clamp to its last entry,
    /// the same clamp `GTable::delay` applies). Call before the first
    /// `record`/`record_sojourn`.
    pub fn enable_streaming(&mut self, bounds: Vec<Vec<f64>>) {
        self.streaming = true;
        self.bounds = bounds;
        self.latency_hist = Histogram::latency_ms();
    }

    /// Record one measured light-service sojourn (wait + service, ms) at
    /// the parallelism level `y` the controller committed to.
    pub fn record_sojourn(&mut self, light_idx: usize, y: u32, sojourn_ms: f64) {
        if let Some(obs) = self.service_obs.get_mut(light_idx) {
            if self.streaming {
                let bound = self
                    .bounds
                    .get(light_idx)
                    .and_then(|row| row.get((y as usize).min(row.len().saturating_sub(1))))
                    .copied()
                    .unwrap_or(f64::INFINITY);
                obs.record_streamed(sojourn_ms, bound);
            } else {
                obs.record(y, sojourn_ms);
            }
        }
    }

    /// Sample the current pending-work depth (one call per tick).
    pub fn record_queue_depth(&mut self, depth: usize) {
        self.queue_depth.record(depth as f64);
    }

    pub fn record(&mut self, o: TaskOutcome) {
        if self.streaming {
            self.total_tasks += 1;
            self.sum_deadline_ms += o.deadline_ms;
            if let Some(l) = o.latency_ms {
                self.completed += 1;
                if o.on_time() {
                    self.on_time += 1;
                }
                self.latency_hist.record(l);
            }
        } else {
            self.outcomes.push(o);
        }
    }

    /// Count one unrecoverable fault casualty (the task outcome itself is
    /// still recorded through [`Self::record`]).
    pub fn record_fault_drop(&mut self) {
        self.fault_drops += 1;
    }

    /// Count one fault-cancelled execution recovered on another replica.
    pub fn record_reroute(&mut self) {
        self.reroute_recovered += 1;
    }

    /// Count one retry cycle (cancellation + backoff) entered.
    pub fn record_retry(&mut self) {
        self.retries += 1;
    }

    /// Count one hedged standby execution booked.
    pub fn record_hedge(&mut self) {
        self.hedges += 1;
    }

    /// Count one checkpoint/restart rejoin completed.
    pub fn record_restore(&mut self) {
        self.checkpoint_restores += 1;
    }

    pub fn len(&self) -> usize {
        if self.streaming {
            self.total_tasks
        } else {
            self.outcomes.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fold into trial metrics, attaching the cost book's totals.
    pub fn finish(self, costs: &CostBook) -> TrialMetrics {
        let b = costs.breakdown();
        if self.streaming {
            let mean_deadline_ms = if self.total_tasks > 0 {
                self.sum_deadline_ms / self.total_tasks as f64
            } else {
                0.0
            };
            return TrialMetrics {
                total_tasks: self.total_tasks,
                completed: self.completed,
                on_time: self.on_time,
                total_cost: b.total(),
                core_cost: b.core_total(),
                light_cost: b.light_total(),
                latencies_ms: Vec::new(),
                mean_deadline_ms,
                service_obs: self.service_obs,
                queue_depth: self.queue_depth,
                latency_hist: self.latency_hist,
                des_events: 0,
                vq_residual: 0,
                fault_drops: self.fault_drops,
                reroute_recovered: self.reroute_recovered,
                retries: self.retries,
                hedges: self.hedges,
                checkpoint_restores: self.checkpoint_restores,
                cold_starts: 0,
                pool_scale_events: 0,
                pool_scale_to_zero: 0,
                pool_replica_slot_seconds: 0.0,
                pool_size: Histogram::default(),
            };
        }
        let total_tasks = self.outcomes.len();
        let completed = self.outcomes.iter().filter(|o| o.completed()).count();
        let on_time = self.outcomes.iter().filter(|o| o.on_time()).count();
        let mut latencies_ms: Vec<f64> = self
            .outcomes
            .iter()
            .filter_map(|o| o.latency_ms)
            .collect();
        // Sorted once here; `latency_percentile` relies on it. This also
        // makes the stream insensitive to engine completion order, so
        // paired slotted-vs-DES comparisons diff multisets, not schedules.
        latencies_ms.sort_by(f64::total_cmp);
        // Fill the histogram here too, so the field is mode-independent
        // (a deterministic function of the latency multiset either way).
        let mut latency_hist = Histogram::latency_ms();
        for &l in &latencies_ms {
            latency_hist.record(l);
        }
        let mean_deadline_ms = if total_tasks > 0 {
            self.outcomes.iter().map(|o| o.deadline_ms).sum::<f64>() / total_tasks as f64
        } else {
            0.0
        };
        TrialMetrics {
            total_tasks,
            completed,
            on_time,
            total_cost: b.total(),
            core_cost: b.core_total(),
            light_cost: b.light_total(),
            latencies_ms,
            mean_deadline_ms,
            service_obs: self.service_obs,
            queue_depth: self.queue_depth,
            latency_hist,
            des_events: 0,
            vq_residual: 0,
            fault_drops: self.fault_drops,
            reroute_recovered: self.reroute_recovered,
            retries: self.retries,
            hedges: self.hedges,
            checkpoint_restores: self.checkpoint_restores,
            cold_starts: 0,
            pool_scale_events: 0,
            pool_scale_to_zero: 0,
            pool_replica_slot_seconds: 0.0,
            pool_size: Histogram::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(lat: Option<f64>, dl: f64) -> TaskOutcome {
        TaskOutcome {
            task_id: 0,
            latency_ms: lat,
            deadline_ms: dl,
        }
    }

    #[test]
    fn rates_computed_correctly() {
        let mut c = MetricsCollector::new();
        c.record(outcome(Some(10.0), 20.0)); // on time
        c.record(outcome(Some(30.0), 20.0)); // late
        c.record(outcome(None, 20.0)); // dropped
        let m = c.finish(&CostBook::default());
        assert_eq!(m.total_tasks, 3);
        assert_eq!(m.completed, 2);
        assert_eq!(m.on_time, 1);
        assert!((m.completion_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.on_time_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_trial_has_unit_rates() {
        let m = MetricsCollector::new().finish(&CostBook::default());
        assert_eq!(m.completion_rate(), 1.0);
        assert_eq!(m.on_time_rate(), 1.0);
    }

    #[test]
    fn failover_counters_flow_through() {
        // Recoverable (rerouted) and fatal (payload-destroyed) casualties
        // are tracked independently — the §P4/§P6 tables depend on the
        // distinction.
        let mut c = MetricsCollector::new();
        c.record_retry();
        c.record_retry();
        c.record_reroute();
        c.record_hedge();
        c.record_restore();
        c.record_fault_drop();
        let m = c.finish(&CostBook::default());
        assert_eq!(m.retries, 2);
        assert_eq!(m.reroute_recovered, 1);
        assert_eq!(m.hedges, 1);
        assert_eq!(m.checkpoint_restores, 1);
        assert_eq!(m.fault_drops, 1);
    }

    #[test]
    fn deadline_boundary_counts_on_time() {
        let o = outcome(Some(20.0), 20.0);
        assert!(o.on_time());
        let o2 = outcome(Some(20.000001), 20.0);
        assert!(!o2.on_time());
    }

    #[test]
    fn service_obs_collected_when_enabled() {
        let mut c = MetricsCollector::new();
        c.enable_service_obs(2);
        c.record_sojourn(0, 1, 5.0);
        c.record_sojourn(0, 2, 9.0);
        c.record_sojourn(1, 1, 3.0);
        c.record_sojourn(99, 1, 1.0); // out of range: ignored
        c.record_queue_depth(4);
        let m = c.finish(&CostBook::default());
        assert_eq!(m.service_obs.len(), 2);
        assert_eq!(m.service_obs[0].samples, vec![(1, 5.0), (2, 9.0)]);
        assert_eq!(m.service_obs[0].sojourn.count(), 2);
        assert_eq!(m.service_obs[1].sojourn.count(), 1);
        assert_eq!(m.queue_depth.count(), 1);
        assert!((m.queue_depth.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn service_obs_empty_by_default() {
        let m = MetricsCollector::new().finish(&CostBook::default());
        assert!(m.service_obs.is_empty());
        assert!(m.queue_depth.is_empty());
    }

    #[test]
    fn latency_percentiles() {
        let mut c = MetricsCollector::new();
        // Recorded in reverse: `finish` must sort so percentiles hold.
        for i in (1..=100).rev() {
            c.record(outcome(Some(i as f64), 1000.0));
        }
        let m = c.finish(&CostBook::default());
        assert!(m.latencies_ms.windows(2).all(|w| w[0] <= w[1]));
        assert!((m.latency_percentile(0.5) - 50.5).abs() < 1.0);
        assert!(m.latency_percentile(0.99) >= 99.0);
    }

    #[test]
    fn latency_percentile_of_empty_trial_is_zero() {
        // Regression: an empty latency vec used to reach `quantile` and
        // come back NaN, poisoning any table built from a hollow trial.
        let m = MetricsCollector::new().finish(&CostBook::default());
        assert_eq!(m.latency_percentile(0.5), 0.0);
        assert_eq!(m.latency_percentile(0.99), 0.0);

        let mut drops = MetricsCollector::new();
        drops.record(outcome(None, 20.0)); // admitted but never completed
        let m = drops.finish(&CostBook::default());
        assert_eq!(m.latency_percentile(0.5), 0.0);
    }

    #[test]
    fn streaming_counts_match_retained() {
        // The streaming collector must agree with the retained one on
        // every aggregate: counts, rates, mean deadline, histogram-level
        // latency distribution, and per-service sojourn aggregates.
        let mut ret = MetricsCollector::new();
        let mut str_ = MetricsCollector::new();
        ret.enable_service_obs(2);
        str_.enable_service_obs(2);
        // Bound 10ms at every y for service 0, 4ms for service 1.
        str_.enable_streaming(vec![vec![10.0; 3], vec![4.0; 3]]);
        for c in [&mut ret, &mut str_] {
            c.record(outcome(Some(10.0), 20.0)); // on time
            c.record(outcome(Some(30.0), 20.0)); // late
            c.record(outcome(None, 20.0)); // dropped
            c.record_sojourn(0, 1, 5.0); // within bound
            c.record_sojourn(0, 2, 12.0); // violates 10.0
            c.record_sojourn(1, 1, 3.0); // within bound
        }
        let r = ret.finish(&CostBook::default());
        let s = str_.finish(&CostBook::default());
        assert_eq!((s.total_tasks, s.completed, s.on_time), (3, 2, 1));
        assert_eq!(s.total_tasks, r.total_tasks);
        assert_eq!(s.mean_deadline_ms, r.mean_deadline_ms);
        assert_eq!(s.latency_hist, r.latency_hist);
        assert!(s.latencies_ms.is_empty(), "streaming retains no raw latencies");
        assert!(s.service_obs[0].samples.is_empty());
        assert_eq!(s.service_obs[0].sojourn.count(), 2);
        assert_eq!(s.service_obs[0].violations, 1);
        assert!((s.service_obs[0].sum_bound_ms - 20.0).abs() < 1e-12);
        assert_eq!(s.service_obs[1].violations, 0);
        // Percentiles answer from the histogram, approximately.
        let p50 = s.latency_percentile(0.5);
        assert!(p50 > 0.0 && (p50 - r.latency_percentile(0.5)).abs() / p50 < 0.2);
    }

    #[test]
    fn streaming_bound_lookup_clamps_y() {
        let mut c = MetricsCollector::new();
        c.enable_service_obs(1);
        c.enable_streaming(vec![vec![10.0, 10.0, 4.0]]); // y=2 → 4.0
        c.record_sojourn(0, 9, 5.0); // y past the row end clamps to 4.0
        let m = c.finish(&CostBook::default());
        assert_eq!(m.service_obs[0].violations, 1);
    }

    #[test]
    fn latency_percentile_handles_unsorted_hand_built_metrics() {
        // Defensive path: a hand-assembled TrialMetrics (tests, external
        // tools) with unsorted latencies still answers correctly.
        let m = TrialMetrics {
            latencies_ms: vec![30.0, 10.0, 20.0],
            ..TrialMetrics::default()
        };
        assert!((m.latency_percentile(0.5) - 20.0).abs() < 1e-9);
    }
}
