//! Distribution statistics: quantiles, summaries, and Gaussian KDE for
//! the violin plots of Fig. 3.

/// Linear-interpolated quantile of a **sorted** slice; `p ∈ [0, 1]`.
pub fn quantile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let p = p.clamp(0.0, 1.0);
    let idx = p * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = idx - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Five-number-plus summary of a sample.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub q25: f64,
    pub median: f64,
    pub q75: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                q25: f64::NAN,
                median: f64::NAN,
                q75: f64::NAN,
                max: f64::NAN,
            };
        }
        let mut s = xs.to_vec();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            q25: quantile(&s, 0.25),
            median: quantile(&s, 0.5),
            q75: quantile(&s, 0.75),
            max: s[n - 1],
        }
    }

    /// One-line report row.
    pub fn row(&self) -> String {
        format!(
            "n={} mean={:.4} std={:.4} min={:.4} q25={:.4} med={:.4} q75={:.4} max={:.4}",
            self.n, self.mean, self.std, self.min, self.q25, self.median, self.q75, self.max
        )
    }
}

/// Violin-plot data: a Gaussian KDE evaluated on a uniform grid — exactly
/// what a plotting frontend needs to draw Fig. 3's violins.
#[derive(Clone, Debug)]
pub struct ViolinData {
    pub grid: Vec<f64>,
    pub density: Vec<f64>,
    pub summary: Summary,
}

/// Gaussian KDE with Silverman's rule-of-thumb bandwidth.
pub fn kde_violin(xs: &[f64], grid_points: usize) -> ViolinData {
    let summary = Summary::of(xs);
    if xs.is_empty() || grid_points == 0 {
        return ViolinData {
            grid: vec![],
            density: vec![],
            summary,
        };
    }
    let n = xs.len() as f64;
    // Silverman bandwidth; guard zero-variance samples.
    let h = (1.06 * summary.std * n.powf(-0.2)).max(1e-9);
    let lo = summary.min - 3.0 * h;
    let hi = summary.max + 3.0 * h;
    let grid: Vec<f64> = (0..grid_points)
        .map(|i| lo + (hi - lo) * i as f64 / (grid_points - 1).max(1) as f64)
        .collect();
    let norm = 1.0 / (n * h * (2.0 * std::f64::consts::PI).sqrt());
    let density: Vec<f64> = grid
        .iter()
        .map(|&g| {
            norm * xs
                .iter()
                .map(|&x| (-0.5 * ((g - x) / h).powi(2)).exp())
                .sum::<f64>()
        })
        .collect();
    ViolinData {
        grid,
        density,
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_interpolates() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&s, 0.0), 1.0);
        assert_eq!(quantile(&s, 1.0), 4.0);
        assert!((quantile(&s, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(s.n, 8);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.std - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn kde_integrates_to_one() {
        let xs: Vec<f64> = (0..200).map(|i| (i % 13) as f64 * 0.5).collect();
        let v = kde_violin(&xs, 512);
        let dx = v.grid[1] - v.grid[0];
        let integral: f64 = v.density.iter().sum::<f64>() * dx;
        assert!(
            (integral - 1.0).abs() < 0.02,
            "KDE should integrate to ~1, got {integral}"
        );
    }

    #[test]
    fn kde_peak_near_mode() {
        let xs = vec![5.0; 50];
        let v = kde_violin(&xs, 101);
        let peak_idx = v
            .density
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert!((v.grid[peak_idx] - 5.0).abs() < 0.1);
    }

    #[test]
    fn kde_handles_empty() {
        let v = kde_violin(&[], 64);
        assert!(v.grid.is_empty());
    }
}
