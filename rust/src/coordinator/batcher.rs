//! Dynamic batching: flush on size or age, whichever comes first.

use std::time::{Duration, Instant};

use super::Request;

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are pending.
    pub max_batch: usize,
    /// Flush once the oldest pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Accumulates requests into batches under a [`BatchPolicy`].
pub struct Batcher {
    policy: BatchPolicy,
    pending: Vec<Request>,
    oldest: Option<Instant>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            pending: Vec::with_capacity(policy.max_batch),
            oldest: None,
        }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Add a request; returns a full batch when the size trigger fires.
    pub fn push(&mut self, req: Request) -> Option<Vec<Request>> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(req);
        if self.pending.len() >= self.policy.max_batch {
            return self.take();
        }
        None
    }

    /// Returns a batch if the oldest pending request has aged out.
    pub fn poll(&mut self) -> Option<Vec<Request>> {
        match self.oldest {
            Some(t) if t.elapsed() >= self.policy.max_wait && !self.pending.is_empty() => {
                self.take()
            }
            _ => None,
        }
    }

    /// Drain whatever is pending (shutdown path).
    pub fn flush(&mut self) -> Option<Vec<Request>> {
        if self.pending.is_empty() {
            None
        } else {
            self.take()
        }
    }

    fn take(&mut self) -> Option<Vec<Request>> {
        self.oldest = None;
        Some(std::mem::take(&mut self.pending))
    }
}
