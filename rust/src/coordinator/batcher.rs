//! Dynamic batching: flush on size or age, whichever comes first.
//!
//! The age trigger runs on an explicit millisecond clock (`*_at` methods)
//! so the same batcher serves two worlds: the serving runtime feeds it
//! wall-clock time (the convenience `push`/`poll` methods measure from an
//! internal origin), while the DES engine feeds it *simulated* time and
//! gets deterministic, reproducible age-based flushes.

use std::time::{Duration, Instant};

use super::Request;

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are pending.
    pub max_batch: usize,
    /// Flush once the oldest pending request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }
    }
}

impl BatchPolicy {
    /// Policy from a simulated-milliseconds wait (DES path).
    pub fn with_wait_ms(max_batch: usize, max_wait_ms: f64) -> Self {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_secs_f64((max_wait_ms.max(0.0)) * 1e-3),
        }
    }

    /// The age trigger in milliseconds.
    pub fn max_wait_ms(&self) -> f64 {
        self.max_wait.as_secs_f64() * 1e3
    }
}

/// Accumulates items into batches under a [`BatchPolicy`].
///
/// Generic over the item type: the serving coordinator batches
/// [`Request`]s (the default), the DES engine batches `(task, stage)`
/// keys per light-service station.
#[derive(Debug)]
pub struct Batcher<T = Request> {
    policy: BatchPolicy,
    pending: Vec<T>,
    /// Clock reading (ms) when the oldest pending item was pushed.
    oldest_ms: Option<f64>,
    /// Origin for the wall-clock convenience methods.
    origin: Instant,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            pending: Vec::with_capacity(policy.max_batch),
            oldest_ms: None,
            origin: Instant::now(),
        }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Add an item at explicit time `now_ms`; returns a full batch when
    /// the size trigger fires.
    pub fn push_at(&mut self, item: T, now_ms: f64) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest_ms = Some(now_ms);
        }
        self.pending.push(item);
        if self.pending.len() >= self.policy.max_batch {
            return self.take();
        }
        None
    }

    /// Returns a batch if, at explicit time `now_ms`, the oldest pending
    /// item has aged out.
    pub fn poll_at(&mut self, now_ms: f64) -> Option<Vec<T>> {
        match self.oldest_ms {
            Some(t) if now_ms - t >= self.policy.max_wait_ms() && !self.pending.is_empty() => {
                self.take()
            }
            _ => None,
        }
    }

    /// Absolute time (ms, same clock as the pushes) when the age trigger
    /// fires — the DES schedules its batch-flush event here.
    pub fn age_deadline_ms(&self) -> Option<f64> {
        self.oldest_ms.map(|t| t + self.policy.max_wait_ms())
    }

    /// Add an item on the wall clock (serving runtime path).
    pub fn push(&mut self, item: T) -> Option<Vec<T>> {
        let now_ms = self.origin.elapsed().as_secs_f64() * 1e3;
        self.push_at(item, now_ms)
    }

    /// Age-poll on the wall clock (serving runtime path).
    pub fn poll(&mut self) -> Option<Vec<T>> {
        let now_ms = self.origin.elapsed().as_secs_f64() * 1e3;
        self.poll_at(now_ms)
    }

    /// Drain whatever is pending (shutdown path).
    pub fn flush(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            None
        } else {
            self.take()
        }
    }

    fn take(&mut self) -> Option<Vec<T>> {
        self.oldest_ms = None;
        Some(std::mem::take(&mut self.pending))
    }
}
