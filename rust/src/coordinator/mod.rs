//! Serving coordinator: the Layer-3 runtime that serves inference
//! requests through the AOT-compiled core-MS compute with dynamic
//! batching — the "beyond-simulation" deployment of the paper's system
//! (`examples/serve_trace.rs` drives it end-to-end).
//!
//! Leader/worker shape (std threads; no async runtime is available
//! offline): a bounded submission channel feeds a batcher thread that
//! groups requests by the compiled batch size (or a timeout, whichever
//! first) and hands batches to worker threads, each owning its own PJRT
//! executable. Latency/throughput are recorded per request.

mod batcher;
mod failover;
mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use failover::{
    compile_worker_events, parse_fault_spec, CheckpointConfig, FailoverConfig, FailoverPolicy,
    FailoverStats, ReplayConfig, ReplayReport, ReplayServer, RetryPolicy, VirtualRequest,
    WorkerEvent,
};
pub use server::{Coordinator, ServeConfig, ServeError, ServeReport};

/// One inference request travelling through the coordinator.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Flattened `[L, D]` activations (one batch slot).
    pub data: Vec<f32>,
    /// Submission timestamp.
    pub submitted: std::time::Instant,
    /// Client deadline (for the on-time accounting).
    pub deadline_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn batch_policy_flushes_on_size() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        assert!(b.push(req(1)).is_none());
        assert!(b.push(req(2)).is_none());
        let batch = b.push(req(3)).expect("size trigger");
        assert_eq!(batch.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn batch_policy_flushes_on_timeout() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        assert!(b.push(req(1)).is_none());
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.poll().expect("timeout trigger");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn sim_time_batcher_is_deterministic() {
        // DES path: explicit clock, no sleeping, generic item type.
        let mut b: Batcher<u64> = Batcher::new(BatchPolicy::with_wait_ms(8, 2.0));
        assert!(b.push_at(1, 100.0).is_none());
        assert_eq!(b.age_deadline_ms(), Some(102.0));
        assert!(b.poll_at(101.9).is_none(), "not aged yet");
        let batch = b.poll_at(102.0).expect("age trigger at exactly max_wait");
        assert_eq!(batch, vec![1]);
        assert!(b.age_deadline_ms().is_none());
        // Size trigger fires regardless of the clock.
        let mut b: Batcher<u64> = Batcher::new(BatchPolicy::with_wait_ms(2, 1000.0));
        assert!(b.push_at(1, 0.0).is_none());
        let batch = b.push_at(2, 0.0).expect("size trigger");
        assert_eq!(batch, vec![1, 2]);
    }

    #[test]
    fn empty_batcher_polls_none() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.poll().is_none());
        assert!(b.flush().is_none());
    }

    #[test]
    fn flush_drains_partial_batch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_secs(10),
        });
        b.push(req(1));
        b.push(req(2));
        let batch = b.flush().expect("explicit flush");
        assert_eq!(batch.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn empty_report_rates_are_defined_zero() {
        // Regression: a run that served nothing used to report a 100%
        // on-time rate (0/0 defaulting to 1.0) and an elapsed-dependent
        // throughput. Both are defined as exactly 0.0.
        let r = ServeReport {
            served: 0,
            rejected: 7,
            on_time: 0,
            batches: 0,
            elapsed: Duration::from_secs(0),
            latency_ms: crate::metrics::Summary::of(&[]),
            batch_fill: 0.0,
            failover: FailoverStats::default(),
        };
        assert_eq!(r.on_time_rate(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
    }

    fn req(id: u64) -> Request {
        Request {
            id,
            data: vec![0.0; 4],
            submitted: Instant::now(),
            deadline_ms: 50.0,
        }
    }
}
