//! The leader loop: bounded admission, batching, worker dispatch — and,
//! when a [`FailoverConfig`] is armed, fault-aware re-routing: a worker
//! that "dies" mid-batch (its mapped edge server is down in the replayed
//! schedule) loses its results and re-routes the batch to the surviving
//! pool with bounded, jittered backoff; new admissions are shed first and
//! accepted work is never abandoned (`in_flight` gates shutdown).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::Summary;
use crate::obs::{Span, SpanKind, TraceRecorder};
use crate::runtime::{shapes, MsBlockAccel, Runtime};

use super::batcher::{BatchPolicy, Batcher};
use super::failover::{compile_worker_events, FailoverConfig, FailoverStats, WorkerEvent};
use super::Request;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads, each with its own PJRT executable.
    pub workers: usize,
    /// Admission queue capacity; beyond it `submit` reports backpressure.
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Execute the real `msblock` artifact (true) or a calibrated no-op
    /// (false — for harness overhead measurements in `bench_coordinator`).
    pub real_compute: bool,
    /// Artifact directory (for `real_compute`).
    pub artifact_dir: std::path::PathBuf,
    /// Fault schedule + retry/checkpoint policy; `None` serves exactly as
    /// before this field existed (the fault-free path is untouched).
    pub failover: Option<FailoverConfig>,
    /// Optional span sink: workers record one `Serve` span per request
    /// (wall-clock ms relative to coordinator start). `None` — the
    /// default — adds no locking or allocation to the serving path.
    pub trace: Option<Arc<Mutex<TraceRecorder>>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            real_compute: true,
            artifact_dir: Runtime::default_dir(),
            failover: None,
            trace: None,
        }
    }
}

/// Serving errors.
#[derive(Debug)]
pub enum ServeError {
    /// Admission queue full (backpressure signal to the client).
    Saturated,
    /// Coordinator already shut down.
    Closed,
    /// Artifact/PJRT failure at startup.
    Runtime(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Saturated => write!(f, "admission queue saturated"),
            ServeError::Closed => write!(f, "coordinator is shut down"),
            ServeError::Runtime(e) => write!(f, "runtime failure: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Final serving report.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub served: u64,
    pub rejected: u64,
    pub on_time: u64,
    pub batches: u64,
    pub elapsed: Duration,
    pub latency_ms: Summary,
    pub batch_fill: f64,
    /// Failover counters; all-zero without a [`FailoverConfig`].
    pub failover: FailoverStats,
}

impl ServeReport {
    /// Served requests per second; a run that served nothing has, by
    /// definition, zero throughput (not a 0/0 artifact).
    pub fn throughput_rps(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.served as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// On-time fraction over *served* requests. Zero served means zero
    /// demonstrated timeliness — defined as `0.0`, never a divide by
    /// zero (and no longer the misleading `1.0` it used to report).
    pub fn on_time_rate(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.on_time as f64 / self.served as f64
    }
}

/// Failover runtime state shared by leader, workers, and the timeline
/// thread. Absent (`None`) on the fault-free path.
struct FailShared {
    /// Per-worker outage depth (overlapping outages nest); up iff 0.
    down: Vec<AtomicU32>,
    reroutes: AtomicU64,
    retries: AtomicU64,
    shed: AtomicU64,
    restores: AtomicU64,
    exhausted: AtomicU64,
    /// Batches handed to the dispatch channel and not yet fully served;
    /// shutdown refuses to stop workers until this drains to zero — the
    /// "never abandon accepted work" guarantee.
    in_flight: AtomicU64,
    /// Drain phase: outages are no longer honored (the schedule's
    /// recoveries all fire eventually; shutdown fast-forwards them so
    /// accepted work completes).
    drain: AtomicBool,
}

struct Shared {
    latencies_ms: Mutex<Vec<f64>>,
    served: AtomicU64,
    on_time: AtomicU64,
    batches: AtomicU64,
    slots_filled: AtomicU64,
    stop: AtomicBool,
    fail: Option<Arc<FailShared>>,
    /// Coordinator epoch; serving-path spans are stamped relative to it.
    started: Instant,
}

/// The serving coordinator (leader thread + worker pool).
pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    leader: Option<JoinHandle<()>>,
    timeline: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    rejected: AtomicU64,
    started: Instant,
}

impl Coordinator {
    /// Start the coordinator: leader + `cfg.workers` PJRT workers (+ a
    /// fault-timeline thread when failover is armed).
    pub fn start(cfg: ServeConfig) -> Result<Self, ServeError> {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
        let (btx, brx) = sync_channel::<(Vec<Request>, u32)>(cfg.workers * 2);
        let fail: Option<(Arc<FailShared>, Vec<WorkerEvent>)> =
            cfg.failover.as_ref().map(|fo| {
                let f = Arc::new(FailShared {
                    down: (0..cfg.workers).map(|_| AtomicU32::new(0)).collect(),
                    reroutes: AtomicU64::new(0),
                    retries: AtomicU64::new(0),
                    shed: AtomicU64::new(0),
                    restores: AtomicU64::new(0),
                    exhausted: AtomicU64::new(0),
                    in_flight: AtomicU64::new(0),
                    drain: AtomicBool::new(false),
                });
                let events = compile_worker_events(
                    &fo.schedule,
                    cfg.workers,
                    fo.num_eds,
                    &fo.policy.checkpoint,
                );
                (f, events)
            });
        let started = Instant::now();
        let shared = Arc::new(Shared {
            latencies_ms: Mutex::new(Vec::new()),
            served: AtomicU64::new(0),
            on_time: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            slots_filled: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            fail: fail.as_ref().map(|(f, _)| Arc::clone(f)),
            started,
        });

        // Validate the artifact once up-front (fail fast on `make artifacts`
        // omissions), then let each worker construct its own client: PJRT
        // handles are !Send in the vendored crate, so they must be born on
        // the thread that uses them.
        if cfg.real_compute {
            let rt = Runtime::cpu(&cfg.artifact_dir)
                .map_err(|e| ServeError::Runtime(e.to_string()))?;
            MsBlockAccel::load(&rt).map_err(|e| ServeError::Runtime(e.to_string()))?;
        }
        let brx = Arc::new(Mutex::new(brx));
        let mut workers = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let brx = Arc::clone(&brx);
            let shared = Arc::clone(&shared);
            let cfg2 = cfg.clone();
            // Only failover workers hold a dispatch sender (to re-route);
            // on the fault-free path the channel must disconnect when the
            // leader drops it, exactly as before.
            let wtx = shared.fail.is_some().then(|| btx.clone());
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fmedge-worker-{wid}"))
                    .spawn(move || {
                        let accel = if cfg2.real_compute {
                            Runtime::cpu(&cfg2.artifact_dir)
                                .and_then(|rt| MsBlockAccel::load_with_retry(&rt, 3))
                                .ok()
                        } else {
                            None
                        };
                        worker_loop(wid, brx, shared, accel, &cfg2, wtx)
                    })
                    .expect("spawn worker"),
            );
        }

        // Fault timeline: replays the compiled worker outages in wall
        // time, flipping per-worker down flags. Sleeps in short steps so
        // the drain phase can fast-forward it.
        let timeline = fail.as_ref().map(|(f, events)| {
            let f = Arc::clone(f);
            let events = events.clone();
            let checkpointing = cfg
                .failover
                .as_ref()
                .map_or(false, |fo| fo.policy.checkpoint.enabled());
            std::thread::Builder::new()
                .name("fmedge-faultline".into())
                .spawn(move || timeline_loop(f, events, started, checkpointing))
                .expect("spawn timeline")
        });

        // Leader: admission -> batching -> dispatch.
        let leader = {
            let shared = Arc::clone(&shared);
            let policy = cfg.batch;
            std::thread::Builder::new()
                .name("fmedge-leader".into())
                .spawn(move || leader_loop(rx, btx, shared, policy))
                .expect("spawn leader")
        };

        Ok(Coordinator {
            tx: Some(tx),
            leader: Some(leader),
            timeline,
            workers,
            shared,
            rejected: AtomicU64::new(0),
            started,
        })
    }

    /// Submit one request; `Err(Saturated)` signals backpressure (counted
    /// as shed load when failover is armed: degradation rejects *new*
    /// admissions first).
    pub fn submit(&self, req: Request) -> Result<(), ServeError> {
        let tx = self.tx.as_ref().ok_or(ServeError::Closed)?;
        match tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                if let Some(f) = &self.shared.fail {
                    f.shed.fetch_add(1, Ordering::Relaxed);
                }
                Err(ServeError::Saturated)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::Closed),
        }
    }

    /// Drain the pipeline and return the final report. With failover
    /// armed, shutdown enters the drain phase (outages fast-forwarded to
    /// their recoveries) and waits for every accepted batch to be served
    /// before stopping the pool — accepted work is never abandoned.
    pub fn shutdown(mut self) -> ServeReport {
        drop(self.tx.take()); // closes the admission channel
        if let Some(l) = self.leader.take() {
            let _ = l.join();
        }
        if let Some(f) = &self.shared.fail {
            f.drain.store(true, Ordering::SeqCst);
            if let Some(t) = self.timeline.take() {
                let _ = t.join();
            }
            while f.in_flight.load(Ordering::SeqCst) > 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let latencies = self.shared.latencies_ms.lock().unwrap();
        let batches = self.shared.batches.load(Ordering::Relaxed);
        let filled = self.shared.slots_filled.load(Ordering::Relaxed);
        let failover = match &self.shared.fail {
            None => FailoverStats::default(),
            Some(f) => FailoverStats {
                reroutes: f.reroutes.load(Ordering::Relaxed),
                retries: f.retries.load(Ordering::Relaxed),
                hedges: 0, // wall-clock pool work-steals; hedging is
                // exercised by the virtual replay and both engines
                shed: f.shed.load(Ordering::Relaxed),
                checkpoint_restores: f.restores.load(Ordering::Relaxed),
                retry_exhausted: f.exhausted.load(Ordering::Relaxed),
                abandoned: f.in_flight.load(Ordering::SeqCst),
            },
        };
        ServeReport {
            served: self.shared.served.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            on_time: self.shared.on_time.load(Ordering::Relaxed),
            batches,
            elapsed: self.started.elapsed(),
            latency_ms: Summary::of(&latencies),
            batch_fill: if batches == 0 {
                0.0
            } else {
                filled as f64 / (batches as f64 * shapes::MSBLOCK_B as f64)
            },
            failover,
        }
    }
}

/// Replay the compiled worker outages in wall time. Short sleep steps so
/// `drain` can cut the replay off at shutdown (remaining recoveries are
/// implied: the drain phase treats every worker as up).
fn timeline_loop(
    f: Arc<FailShared>,
    events: Vec<WorkerEvent>,
    started: Instant,
    checkpointing: bool,
) {
    for ev in events {
        loop {
            if f.drain.load(Ordering::SeqCst) {
                return;
            }
            let now_ms = started.elapsed().as_secs_f64() * 1e3;
            if now_ms >= ev.at_ms {
                break;
            }
            let wait = (ev.at_ms - now_ms).min(5.0);
            std::thread::sleep(Duration::from_secs_f64(wait.max(0.05) / 1e3));
        }
        let w = &f.down[ev.worker];
        if ev.up {
            let prev = w.load(Ordering::SeqCst);
            w.store(prev.saturating_sub(1), Ordering::SeqCst);
            if prev == 1 && checkpointing {
                f.restores.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            w.fetch_add(1, Ordering::SeqCst);
        }
    }
}

fn leader_loop(
    rx: Receiver<Request>,
    btx: SyncSender<(Vec<Request>, u32)>,
    shared: Arc<Shared>,
    policy: BatchPolicy,
) {
    let mut batcher = Batcher::new(policy);
    let send = |batch: Vec<Request>| -> Result<(), ()> {
        if let Some(f) = &shared.fail {
            f.in_flight.fetch_add(1, Ordering::SeqCst);
        }
        match btx.send((batch, 0)) {
            Ok(()) => Ok(()),
            Err(_) => {
                if let Some(f) = &shared.fail {
                    f.in_flight.fetch_sub(1, Ordering::SeqCst);
                }
                Err(())
            }
        }
    };
    loop {
        match rx.recv_timeout(policy.max_wait.max(Duration::from_micros(200))) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req) {
                    if send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll() {
                    if send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.flush() {
                    let _ = send(batch);
                }
                return; // drops btx: fault-free workers drain and exit
            }
        }
    }
}

fn worker_loop(
    wid: usize,
    brx: Arc<Mutex<Receiver<(Vec<Request>, u32)>>>,
    shared: Arc<Shared>,
    accel: Option<MsBlockAccel>,
    cfg: &ServeConfig,
    wtx: Option<SyncSender<(Vec<Request>, u32)>>,
) {
    let slot = shapes::MSBLOCK_L * shapes::MSBLOCK_D;
    let mut buf = vec![0f32; shapes::MSBLOCK_B * slot];
    let retry = cfg.failover.as_ref().map(|fo| fo.policy.retry);
    let is_down = |shared: &Shared| -> bool {
        match &shared.fail {
            Some(f) if !f.drain.load(Ordering::SeqCst) => {
                f.down[wid].load(Ordering::SeqCst) > 0
            }
            _ => false,
        }
    };
    loop {
        // A down worker takes no new work (its mapped edge server is
        // dark); the surviving pool keeps draining the shared channel.
        if is_down(&shared) {
            std::thread::sleep(Duration::from_micros(250));
            if shared.stop.load(Ordering::SeqCst) {
                return;
            }
            continue;
        }
        let (batch, attempts) = {
            let rx = brx.lock().unwrap();
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(b) => b,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if shared.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        };
        // Failover defers result recording until the batch is known to
        // have completed on a live node; the fault-free path records
        // inline per chunk, exactly as it always has.
        let deferred = shared.fail.is_some();
        let record = |chunk: &[Request]| {
            shared.batches.fetch_add(1, Ordering::Relaxed);
            shared
                .slots_filled
                .fetch_add(chunk.len() as u64, Ordering::Relaxed);
            let mut lat = shared.latencies_ms.lock().unwrap();
            for req in chunk {
                let ms = req.submitted.elapsed().as_secs_f64() * 1e3;
                lat.push(ms);
                shared.served.fetch_add(1, Ordering::Relaxed);
                if ms <= req.deadline_ms {
                    shared.on_time.fetch_add(1, Ordering::Relaxed);
                }
            }
            drop(lat);
            if let Some(tr) = &cfg.trace {
                let mut r = tr.lock().unwrap();
                for req in chunk {
                    let sub_ms = req
                        .submitted
                        .saturating_duration_since(shared.started)
                        .as_secs_f64()
                        * 1e3;
                    r.push_raw(Span {
                        task: req.id,
                        stage: Some(0),
                        attempt: attempts as u64,
                        kind: SpanKind::Serve,
                        start_ms: sub_ms,
                        end_ms: sub_ms + req.submitted.elapsed().as_secs_f64() * 1e3,
                        node: Some(wid),
                        y: 0,
                        cancelled: false,
                    });
                }
            }
        };
        // Pack up to B request slots; surplus requests are chunked.
        for chunk in batch.chunks(shapes::MSBLOCK_B) {
            for (i, req) in chunk.iter().enumerate() {
                let n = req.data.len().min(slot);
                buf[i * slot..i * slot + n].copy_from_slice(&req.data[..n]);
                for x in &mut buf[i * slot + n..(i + 1) * slot] {
                    *x = 0.0;
                }
            }
            if let Some(accel) = &accel {
                // A failed forward is recorded as served-but-late rather
                // than crashing the worker (fault isolation).
                let _ = accel.forward(&buf);
            }
            if !deferred {
                record(chunk);
            }
        }
        if !deferred {
            continue;
        }
        // The node died while this batch executed: its results are lost
        // with it. Re-route to the surviving pool after a bounded,
        // jittered backoff — the request is retried, never dropped.
        if is_down(&shared) {
            if let (Some(f), Some(tx), Some(rp)) = (&shared.fail, &wtx, &retry) {
                let next = attempts + 1;
                f.retries.fetch_add(1, Ordering::Relaxed);
                f.reroutes.fetch_add(batch.len() as u64, Ordering::Relaxed);
                if next == rp.max_attempts + 1 {
                    f.exhausted.fetch_add(batch.len() as u64, Ordering::Relaxed);
                }
                let key = batch.first().map_or(wid as u64, |r| r.id);
                let back = rp.backoff_ms(next, key);
                std::thread::sleep(Duration::from_secs_f64(back.max(0.0) / 1e3));
                if tx.send((batch, next)).is_ok() {
                    continue;
                }
                // Channel gone (cannot happen while the pool lives): fall
                // through and serve locally rather than abandon the batch.
            }
        }
        for chunk in batch.chunks(shapes::MSBLOCK_B) {
            record(chunk);
        }
        if let Some(f) = &shared.fail {
            f.in_flight.fetch_sub(1, Ordering::SeqCst);
        }
    }
}
