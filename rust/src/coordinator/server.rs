//! The leader loop: bounded admission, batching, worker dispatch.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::Summary;
use crate::runtime::{shapes, MsBlockAccel, Runtime};

use super::batcher::{BatchPolicy, Batcher};
use super::Request;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads, each with its own PJRT executable.
    pub workers: usize,
    /// Admission queue capacity; beyond it `submit` reports backpressure.
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Execute the real `msblock` artifact (true) or a calibrated no-op
    /// (false — for harness overhead measurements in `bench_coordinator`).
    pub real_compute: bool,
    /// Artifact directory (for `real_compute`).
    pub artifact_dir: std::path::PathBuf,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            real_compute: true,
            artifact_dir: Runtime::default_dir(),
        }
    }
}

/// Serving errors.
#[derive(Debug)]
pub enum ServeError {
    /// Admission queue full (backpressure signal to the client).
    Saturated,
    /// Coordinator already shut down.
    Closed,
    /// Artifact/PJRT failure at startup.
    Runtime(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Saturated => write!(f, "admission queue saturated"),
            ServeError::Closed => write!(f, "coordinator is shut down"),
            ServeError::Runtime(e) => write!(f, "runtime failure: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Final serving report.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub served: u64,
    pub rejected: u64,
    pub on_time: u64,
    pub batches: u64,
    pub elapsed: Duration,
    pub latency_ms: Summary,
    pub batch_fill: f64,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.served as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    pub fn on_time_rate(&self) -> f64 {
        if self.served == 0 {
            1.0
        } else {
            self.on_time as f64 / self.served as f64
        }
    }
}

struct Shared {
    latencies_ms: Mutex<Vec<f64>>,
    served: AtomicU64,
    on_time: AtomicU64,
    batches: AtomicU64,
    slots_filled: AtomicU64,
    stop: AtomicBool,
}

/// The serving coordinator (leader thread + worker pool).
pub struct Coordinator {
    tx: Option<SyncSender<Request>>,
    leader: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    rejected: AtomicU64,
    started: Instant,
}

impl Coordinator {
    /// Start the coordinator: leader + `cfg.workers` PJRT workers.
    pub fn start(cfg: ServeConfig) -> Result<Self, ServeError> {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
        let (btx, brx) = sync_channel::<Vec<Request>>(cfg.workers * 2);
        let shared = Arc::new(Shared {
            latencies_ms: Mutex::new(Vec::new()),
            served: AtomicU64::new(0),
            on_time: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            slots_filled: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });

        // Validate the artifact once up-front (fail fast on `make artifacts`
        // omissions), then let each worker construct its own client: PJRT
        // handles are !Send in the vendored crate, so they must be born on
        // the thread that uses them.
        if cfg.real_compute {
            let rt = Runtime::cpu(&cfg.artifact_dir)
                .map_err(|e| ServeError::Runtime(e.to_string()))?;
            MsBlockAccel::load(&rt).map_err(|e| ServeError::Runtime(e.to_string()))?;
        }
        let brx = Arc::new(Mutex::new(brx));
        let mut workers = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let brx = Arc::clone(&brx);
            let shared = Arc::clone(&shared);
            let cfg2 = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fmedge-worker-{wid}"))
                    .spawn(move || {
                        let accel = if cfg2.real_compute {
                            Runtime::cpu(&cfg2.artifact_dir)
                                .and_then(|rt| MsBlockAccel::load(&rt))
                                .ok()
                        } else {
                            None
                        };
                        worker_loop(brx, shared, accel, &cfg2)
                    })
                    .expect("spawn worker"),
            );
        }

        // Leader: admission -> batching -> dispatch.
        let leader = {
            let shared = Arc::clone(&shared);
            let policy = cfg.batch;
            std::thread::Builder::new()
                .name("fmedge-leader".into())
                .spawn(move || leader_loop(rx, btx, shared, policy))
                .expect("spawn leader")
        };

        Ok(Coordinator {
            tx: Some(tx),
            leader: Some(leader),
            workers,
            shared,
            rejected: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    /// Submit one request; `Err(Saturated)` signals backpressure.
    pub fn submit(&self, req: Request) -> Result<(), ServeError> {
        let tx = self.tx.as_ref().ok_or(ServeError::Closed)?;
        match tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::Saturated)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::Closed),
        }
    }

    /// Drain the pipeline and return the final report.
    pub fn shutdown(mut self) -> ServeReport {
        drop(self.tx.take()); // closes the admission channel
        if let Some(l) = self.leader.take() {
            let _ = l.join();
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let latencies = self.shared.latencies_ms.lock().unwrap();
        let batches = self.shared.batches.load(Ordering::Relaxed);
        let filled = self.shared.slots_filled.load(Ordering::Relaxed);
        ServeReport {
            served: self.shared.served.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            on_time: self.shared.on_time.load(Ordering::Relaxed),
            batches,
            elapsed: self.started.elapsed(),
            latency_ms: Summary::of(&latencies),
            batch_fill: if batches == 0 {
                0.0
            } else {
                filled as f64 / (batches as f64 * shapes::MSBLOCK_B as f64)
            },
        }
    }
}

fn leader_loop(
    rx: Receiver<Request>,
    btx: SyncSender<Vec<Request>>,
    shared: Arc<Shared>,
    policy: BatchPolicy,
) {
    let mut batcher = Batcher::new(policy);
    loop {
        match rx.recv_timeout(policy.max_wait.max(Duration::from_micros(200))) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req) {
                    if btx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll() {
                    if btx.send(batch).is_err() {
                        return;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.flush() {
                    let _ = btx.send(batch);
                }
                drop(btx); // workers drain and exit
                let _ = shared; // lifetime clarity
                return;
            }
        }
    }
}

fn worker_loop(
    brx: Arc<Mutex<Receiver<Vec<Request>>>>,
    shared: Arc<Shared>,
    accel: Option<MsBlockAccel>,
    _cfg: &ServeConfig,
) {
    let slot = shapes::MSBLOCK_L * shapes::MSBLOCK_D;
    let mut buf = vec![0f32; shapes::MSBLOCK_B * slot];
    loop {
        let batch = {
            let rx = brx.lock().unwrap();
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(b) => b,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if shared.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        };
        // Pack up to B request slots; surplus requests are chunked.
        for chunk in batch.chunks(shapes::MSBLOCK_B) {
            for (i, req) in chunk.iter().enumerate() {
                let n = req.data.len().min(slot);
                buf[i * slot..i * slot + n].copy_from_slice(&req.data[..n]);
                for x in &mut buf[i * slot + n..(i + 1) * slot] {
                    *x = 0.0;
                }
            }
            if let Some(accel) = &accel {
                // A failed forward is recorded as served-but-late rather
                // than crashing the worker (fault isolation).
                let _ = accel.forward(&buf);
            }
            shared.batches.fetch_add(1, Ordering::Relaxed);
            shared
                .slots_filled
                .fetch_add(chunk.len() as u64, Ordering::Relaxed);
            let mut lat = shared.latencies_ms.lock().unwrap();
            for req in chunk {
                let ms = req.submitted.elapsed().as_secs_f64() * 1e3;
                lat.push(ms);
                shared.served.fetch_add(1, Ordering::Relaxed);
                if ms <= req.deadline_ms {
                    shared.on_time.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}
