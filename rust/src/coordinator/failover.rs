//! Failover policy for the serving path: retry/backoff/hedging, core
//! checkpoint clocks, and the fault-schedule → worker-outage compiler.
//!
//! One recovery semantics, three consumers:
//!
//! * the live [`super::Coordinator`] (wall-clock threads) re-routes
//!   batches off dying workers and sheds *new* admissions first;
//! * the [`ReplayServer`] here replays the same policy in virtual time —
//!   single-threaded and bit-deterministic, so tests and CI can assert
//!   exact counter equality across runs;
//! * both simulation engines ([`crate::sim`], [`crate::des`]) replay the
//!   same [`RetryPolicy`]/[`CheckpointConfig`] deterministically, so
//!   slotted-vs-DES agreement extends to retried executions.
//!
//! The degradation contract (tentpole acceptance): accepted work is never
//! abandoned unless its payload is provably destroyed. Bounded here means
//! the *backoff growth* and the `retry_exhausted` accounting are bounded
//! by `max_attempts`; persistence is not — the age/deadline drop is the
//! hard lifetime bound, so nothing is ever silently lost.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::time::Duration;

use crate::faults::{FaultEvent, FaultKind, FaultSchedule};
use crate::metrics::Summary;
use crate::obs::{Observer, Span, SpanKind, TraceRecorder};

use super::server::ServeReport;

/// SplitMix64 — the deterministic jitter source. Retries key it by
/// `(task/request id, attempt)`, so every engine and every repeat of a
/// run draws the identical jitter without touching any engine RNG stream.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform in `[0, 1)` from a splitmix draw (53-bit mantissa).
fn unit_f64(key: u64) -> f64 {
    (splitmix64(key) >> 11) as f64 / (1u64 << 53) as f64
}

/// Bounded retry with jittered exponential backoff + optional hedging.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Attempts after which backoff stops growing and the retry is
    /// counted as exhausted (the work itself keeps its age-drop bound).
    pub max_attempts: u32,
    /// First-retry backoff (ms).
    pub base_backoff_ms: f64,
    /// Geometric growth factor per attempt.
    pub multiplier: f64,
    /// Backoff ceiling (ms).
    pub max_backoff_ms: f64,
    /// Jitter: the backoff is scaled by `1 - jitter_frac * U[0,1)`,
    /// keyed deterministically by `(id, attempt)`.
    pub jitter_frac: f64,
    /// Hedge a second attempt when the remaining deadline slack falls
    /// below this fraction of the deadline; `0.0` disables hedging.
    pub hedge_slack_frac: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff_ms: 5.0,
            multiplier: 2.0,
            max_backoff_ms: 80.0,
            jitter_frac: 0.5,
            hedge_slack_frac: 0.25,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (1-based), deterministically
    /// jittered by `key` (callers pass the task/request id).
    pub fn backoff_ms(&self, attempt: u32, key: u64) -> f64 {
        let a = attempt.clamp(1, self.max_attempts.max(1));
        let raw = self.base_backoff_ms * self.multiplier.powi(a as i32 - 1);
        let capped = raw.min(self.max_backoff_ms).max(0.0);
        capped * (1.0 - self.jitter_frac.clamp(0.0, 1.0) * unit_f64(key ^ ((a as u64) << 32)))
    }

    /// Has the bounded-retry budget been spent? (Accounting only — the
    /// caller keeps retrying at the capped backoff until the age drop.)
    pub fn exhausted(&self, attempt: u32) -> bool {
        attempt > self.max_attempts
    }

    /// Hedge decision at dispatch time: fire a duplicate attempt when the
    /// remaining slack is below `hedge_slack_frac` of the deadline.
    pub fn should_hedge(&self, slack_ms: f64, deadline_ms: f64) -> bool {
        self.hedge_slack_frac > 0.0 && slack_ms < self.hedge_slack_frac * deadline_ms
    }

    /// Per-attempt timeout derived from the stage's effective-capacity
    /// budget `g_bound_ms` (the `g_{m,ε}(y)` value the controller
    /// committed to): an attempt gets 1.5× its analytic budget, never
    /// more than the whole task deadline.
    pub fn attempt_timeout_ms(&self, deadline_ms: f64, g_bound_ms: f64) -> f64 {
        (1.5 * g_bound_ms.max(0.0)).min(deadline_ms.max(0.0))
    }
}

/// Checkpoint/restart clocks for core replicas: a periodic lightweight
/// snapshot lets a fail-stopped replica rejoin after `restore_ms`; one
/// that never checkpointed pays the full `cold_start_ms`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CheckpointConfig {
    /// Snapshot cadence (ms); `0.0` disables checkpointing.
    pub period_ms: f64,
    /// Rejoin delay from the last checkpoint.
    pub restore_ms: f64,
    /// Rejoin delay without any checkpoint (full cold start).
    pub cold_start_ms: f64,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        CheckpointConfig {
            period_ms: 50.0,
            restore_ms: 5.0,
            cold_start_ms: 25.0,
        }
    }
}

impl CheckpointConfig {
    pub fn enabled(&self) -> bool {
        self.period_ms > 0.0 && self.period_ms.is_finite()
    }
}

/// The policy pair the engines replay (options structs embed this; the
/// default reproduces the serving coordinator's defaults).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FailoverPolicy {
    pub retry: RetryPolicy,
    pub checkpoint: CheckpointConfig,
}

/// Full failover configuration of the live coordinator: the fault
/// schedule to replay plus the recovery policy.
#[derive(Clone, Debug)]
pub struct FailoverConfig {
    pub schedule: FaultSchedule,
    pub policy: FailoverPolicy,
    /// Edge devices precede edge servers in the paper topology's node
    /// numbering; ES node ids map onto worker indices round-robin.
    pub num_eds: usize,
}

/// Failover counters surfaced on [`ServeReport`]. `abandoned` counts
/// accepted requests dropped without service — the degradation contract
/// keeps it at zero (asserted by tests and the CI smoke).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FailoverStats {
    /// Requests completed by a different worker than their first dispatch.
    pub reroutes: u64,
    /// Retry dispatches scheduled after a fault cancellation.
    pub retries: u64,
    /// Hedged duplicate attempts fired near the deadline.
    pub hedges: u64,
    /// New admissions shed while degraded (graceful degradation sheds
    /// *new* work first; accepted work is never abandoned).
    pub shed: u64,
    /// Replica rejoins served from a checkpoint snapshot.
    pub checkpoint_restores: u64,
    /// Requests whose bounded retry budget ran out (still served late,
    /// never dropped).
    pub retry_exhausted: u64,
    /// Accepted requests dropped without service — must stay zero.
    pub abandoned: u64,
}

impl FailoverStats {
    /// One-line report form (printed by `fmedge serve --faults`).
    pub fn line(&self) -> String {
        format!(
            "rerouted {} retries {} hedges {} shed {} restores {} exhausted {} abandoned {}",
            self.reroutes,
            self.retries,
            self.hedges,
            self.shed,
            self.checkpoint_restores,
            self.retry_exhausted,
            self.abandoned
        )
    }
}

/// One compiled worker-pool outage transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerEvent {
    pub at_ms: f64,
    pub worker: usize,
    pub up: bool,
}

/// Compile a [`FaultSchedule`] onto a worker pool: ES node outages map
/// round-robin onto worker indices (`worker = (node - num_eds) %
/// workers`); a core-replica fail-stop becomes a worker restart whose
/// duration is the checkpoint restore clock (cold start when
/// checkpointing is disabled). Link events and ED nodes do not exist on
/// the serving path and are ignored.
pub fn compile_worker_events(
    schedule: &FaultSchedule,
    workers: usize,
    num_eds: usize,
    checkpoint: &CheckpointConfig,
) -> Vec<WorkerEvent> {
    let mut out = Vec::new();
    if workers == 0 {
        return out;
    }
    let map = |node: usize| -> Option<usize> {
        (node >= num_eds).then(|| (node - num_eds) % workers)
    };
    for ev in schedule.events() {
        match ev.kind {
            FaultKind::NodeDown { node } => {
                if let Some(w) = map(node) {
                    out.push(WorkerEvent { at_ms: ev.time_ms, worker: w, up: false });
                }
            }
            FaultKind::NodeUp { node } => {
                if let Some(w) = map(node) {
                    out.push(WorkerEvent { at_ms: ev.time_ms, worker: w, up: true });
                }
            }
            FaultKind::CoreReplicaFail { node, .. } => {
                if let Some(w) = map(node) {
                    let restart = if checkpoint.enabled() {
                        checkpoint.restore_ms
                    } else {
                        checkpoint.cold_start_ms
                    };
                    out.push(WorkerEvent { at_ms: ev.time_ms, worker: w, up: false });
                    out.push(WorkerEvent {
                        at_ms: ev.time_ms + restart.max(0.0),
                        worker: w,
                        up: true,
                    });
                }
            }
            // Replica restarts pair with the engines' checkpoint/rejoin
            // path; on the worker pool the synthesized pair above already
            // models the restart. Link faults have no serving analogue.
            FaultKind::CoreReplicaRestart { .. }
            | FaultKind::LinkDown { .. }
            | FaultKind::LinkUp { .. }
            | FaultKind::LinkBandwidth { .. } => {}
        }
    }
    out.sort_by(|a, b| {
        a.at_ms
            .total_cmp(&b.at_ms)
            .then_with(|| a.worker.cmp(&b.worker))
            .then_with(|| a.up.cmp(&b.up))
    });
    out
}

/// Parse a `--faults` spec into a [`FaultSchedule`] over the paper
/// topology's node numbering (EDs `0..num_eds`, ESs following).
///
/// Comma-separated forms, times in ms:
/// * `zone@START+DUR` — a contiguous half of the edge servers (at least
///   one, never all when more than one exists) goes down at `START` and
///   recovers `DUR` later;
/// * `esK@START+DUR` — edge server `K` (0-based) alone.
pub fn parse_fault_spec(
    spec: &str,
    num_eds: usize,
    num_ess: usize,
) -> Result<FaultSchedule, String> {
    if num_ess == 0 {
        return Err("topology has no edge servers to fault".into());
    }
    let mut events = Vec::new();
    let mut outage = |nodes: &[usize], start: f64, dur: f64| {
        for &v in nodes {
            events.push(FaultEvent { time_ms: start, kind: FaultKind::NodeDown { node: v } });
            events.push(FaultEvent {
                time_ms: start + dur,
                kind: FaultKind::NodeUp { node: v },
            });
        }
    };
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (head, times) = part
            .split_once('@')
            .ok_or_else(|| format!("`{part}`: expected FORM@START+DUR"))?;
        let (start, dur) = times
            .split_once('+')
            .ok_or_else(|| format!("`{part}`: expected START+DUR after `@`"))?;
        let start: f64 = start
            .trim()
            .parse()
            .map_err(|_| format!("`{part}`: bad start time `{start}`"))?;
        let dur: f64 = dur
            .trim()
            .parse()
            .map_err(|_| format!("`{part}`: bad duration `{dur}`"))?;
        if !(start >= 0.0 && dur > 0.0 && start.is_finite() && dur.is_finite()) {
            return Err(format!("`{part}`: times must be finite, start >= 0, dur > 0"));
        }
        if head == "zone" {
            let mut k = (num_ess / 2).max(1);
            if num_ess > 1 {
                k = k.min(num_ess - 1);
            }
            let zone: Vec<usize> = (0..k).map(|i| num_eds + i).collect();
            outage(&zone, start, dur);
        } else if let Some(idx) = head.strip_prefix("es") {
            let k: usize = idx
                .parse()
                .map_err(|_| format!("`{part}`: bad edge-server index `{idx}`"))?;
            if k >= num_ess {
                return Err(format!(
                    "`{part}`: edge server {k} out of range (topology has {num_ess})"
                ));
            }
            outage(&[num_eds + k], start, dur);
        } else {
            return Err(format!("`{part}`: unknown form `{head}` (zone|esK)"));
        }
    }
    if events.is_empty() {
        return Err("empty fault spec".into());
    }
    Ok(FaultSchedule::from_events(events))
}

// ---------------------------------------------------------------------------
// Virtual-time replay server
// ---------------------------------------------------------------------------

/// One request of a virtual serving run.
#[derive(Clone, Copy, Debug)]
pub struct VirtualRequest {
    pub id: u64,
    pub arrive_ms: f64,
    pub deadline_ms: f64,
}

/// Replay-server configuration (the virtual analogue of
/// [`super::ServeConfig`]).
#[derive(Clone, Debug)]
pub struct ReplayConfig {
    pub workers: usize,
    /// Waiting-queue bound; arrivals beyond it are shed.
    pub queue_capacity: usize,
    /// Deterministic per-request service time (ms) — stands in for the
    /// `g_{m,ε}` budget of the one serving stage.
    pub proc_ms: f64,
    pub policy: FailoverPolicy,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            workers: 2,
            queue_capacity: 1024,
            proc_ms: 2.0,
            policy: FailoverPolicy::default(),
        }
    }
}

/// Outcome of a virtual serving run. Bit-deterministic: identical inputs
/// produce identical counters and latencies, which is what
/// `rust/tests/failover.rs` asserts across repeated runs.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayReport {
    pub accepted: u64,
    pub served: u64,
    pub on_time: u64,
    pub latencies_ms: Vec<f64>,
    /// Virtual time of the last completion.
    pub horizon_ms: f64,
    pub stats: FailoverStats,
}

impl ReplayReport {
    /// Project onto the live coordinator's report type (virtual time
    /// becomes the elapsed duration; batching is per-request here).
    pub fn to_serve_report(&self) -> ServeReport {
        ServeReport {
            served: self.served,
            rejected: self.stats.shed,
            on_time: self.on_time,
            batches: self.served,
            elapsed: Duration::from_secs_f64(self.horizon_ms.max(0.0) / 1e3),
            latency_ms: Summary::of(&self.latencies_ms),
            batch_fill: 1.0,
            failover: self.stats,
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrive(usize),
    Net(usize),
    /// Attempt completion: `(worker, assignment generation)`.
    Done(usize, u64),
    /// Backoff expiry / restart-ready: re-enqueue request `idx`.
    Wake(usize),
}

#[derive(Clone, Copy)]
struct Timed {
    at_ms: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Timed {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Timed {}
impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at_ms
            .partial_cmp(&other.at_ms)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

struct ReqState {
    arrive_ms: f64,
    deadline_ms: f64,
    id: u64,
    attempts: u32,
    completed: bool,
    /// This request was cancelled off a dying worker at least once.
    rerouted: bool,
    exhausted_counted: bool,
}

struct WorkerState {
    /// Outage depth (overlapping down events nest); up iff zero.
    down: u32,
    /// Current assignment: `(request index, assignment generation)`.
    serving: Option<(usize, u64)>,
    /// Not dispatchable before this (restart clock after recovery).
    free_at: f64,
}

/// Deterministic single-threaded replay of the serving path under a
/// fault schedule: same retry/backoff/hedge/shed semantics as the live
/// coordinator, in virtual time. See the module docs for the role split.
pub struct ReplayServer {
    cfg: ReplayConfig,
    outages: Vec<WorkerEvent>,
}

impl ReplayServer {
    pub fn new(cfg: ReplayConfig, schedule: &FaultSchedule, num_eds: usize) -> Self {
        let outages =
            compile_worker_events(schedule, cfg.workers, num_eds, &cfg.policy.checkpoint);
        ReplayServer { cfg, outages }
    }

    /// Serve `arrivals` (sorted by arrival time) to completion.
    pub fn run(&self, arrivals: &[VirtualRequest]) -> ReplayReport {
        self.run_inner(arrivals, None)
    }

    /// Like [`ReplayServer::run`], recording serving-path spans (queue
    /// wait, service, hedges, cancelled attempts, backoff) into `obs`.
    /// Recording is pure observation: the report is identical to the
    /// unobserved run on the same inputs (asserted by tests).
    pub fn run_observed(&self, arrivals: &[VirtualRequest], obs: &mut Observer) -> ReplayReport {
        self.run_inner(arrivals, obs.trace.as_mut())
    }

    fn run_inner(
        &self,
        arrivals: &[VirtualRequest],
        mut rec: Option<&mut TraceRecorder>,
    ) -> ReplayReport {
        let retry = self.cfg.policy.retry;
        let checkpoint = self.cfg.policy.checkpoint;
        let mut heap: BinaryHeap<Reverse<Timed>> = BinaryHeap::new();
        let mut seq = 0u64;
        let mut push = |heap: &mut BinaryHeap<Reverse<Timed>>, seq: &mut u64, at: f64, ev: Ev| {
            *seq += 1;
            heap.push(Reverse(Timed { at_ms: at, seq: *seq, ev }));
        };
        for (i, a) in arrivals.iter().enumerate() {
            push(&mut heap, &mut seq, a.arrive_ms, Ev::Arrive(i));
        }
        for (i, o) in self.outages.iter().enumerate() {
            push(&mut heap, &mut seq, o.at_ms, Ev::Net(i));
        }

        let mut reqs: Vec<ReqState> = arrivals
            .iter()
            .map(|a| ReqState {
                arrive_ms: a.arrive_ms,
                deadline_ms: a.deadline_ms,
                id: a.id,
                attempts: 0,
                completed: false,
                rerouted: false,
                exhausted_counted: false,
            })
            .collect();
        let mut workers: Vec<WorkerState> = (0..self.cfg.workers.max(1))
            .map(|_| WorkerState { down: 0, serving: None, free_at: 0.0 })
            .collect();
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut stats = FailoverStats::default();
        let mut accepted = 0u64;
        let mut served = 0u64;
        let mut on_time = 0u64;
        let mut latencies = Vec::new();
        let mut gen = 0u64;
        let mut horizon = 0.0f64;

        // Pure-observation scratch: spans are accumulated on the side and
        // merged into the recorder at the end, so recording cannot perturb
        // event ordering or any served/latency outcome.
        struct ServeTrace {
            /// Per-request start of the current wait (arrival or re-enqueue).
            wait_since: Vec<f64>,
            /// Per-worker index into `spans` of the in-flight Serve/Hedge span.
            widx: Vec<Option<usize>>,
            spans: Vec<Span>,
        }
        let mut tr: Option<ServeTrace> = rec.as_ref().map(|_| ServeTrace {
            wait_since: arrivals.iter().map(|a| a.arrive_ms).collect(),
            widx: vec![None; self.cfg.workers.max(1)],
            spans: Vec::new(),
        });

        // Dispatch as much queued work as free, healthy workers allow.
        // Hedging fires a duplicate on a second free worker when slack
        // is short; the first completion wins, the duplicate is ignored.
        #[allow(clippy::too_many_arguments)]
        fn dispatch(
            now: f64,
            queue: &mut VecDeque<usize>,
            reqs: &mut [ReqState],
            workers: &mut [WorkerState],
            heap: &mut BinaryHeap<Reverse<Timed>>,
            seq: &mut u64,
            gen: &mut u64,
            stats: &mut FailoverStats,
            retry: &RetryPolicy,
            proc_ms: f64,
            tr: &mut Option<ServeTrace>,
        ) {
            loop {
                let free: Vec<usize> = workers
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| w.down == 0 && w.serving.is_none() && w.free_at <= now)
                    .map(|(i, _)| i)
                    .collect();
                if free.is_empty() {
                    break;
                }
                let ri = loop {
                    match queue.pop_front() {
                        None => return,
                        Some(ri) if !reqs[ri].completed => break ri,
                        Some(_) => continue, // completed by a hedge
                    }
                };
                let slack = reqs[ri].deadline_ms - (now - reqs[ri].arrive_ms);
                // Hedge when the relative slack is short, or when even a
                // single attempt's g-derived budget may no longer fit.
                let hedge = free.len() > 1
                    && (retry.should_hedge(slack, reqs[ri].deadline_ms)
                        || slack < retry.attempt_timeout_ms(reqs[ri].deadline_ms, proc_ms));
                let n_attempts = if hedge { 2 } else { 1 };
                if hedge {
                    stats.hedges += 1;
                }
                for (k, &w) in free.iter().take(n_attempts).enumerate() {
                    *gen += 1;
                    workers[w].serving = Some((ri, *gen));
                    *seq += 1;
                    heap.push(Reverse(Timed {
                        at_ms: now + proc_ms,
                        seq: *seq,
                        ev: Ev::Done(w, *gen),
                    }));
                    if let Some(tr) = tr.as_mut() {
                        if k == 0 {
                            tr.spans.push(Span {
                                task: reqs[ri].id,
                                stage: Some(0),
                                attempt: *gen,
                                kind: SpanKind::QueueWait,
                                start_ms: tr.wait_since[ri].min(now),
                                end_ms: now,
                                node: Some(w),
                                y: 0,
                                cancelled: false,
                            });
                        }
                        let kind = if k == 0 { SpanKind::Serve } else { SpanKind::Hedge };
                        tr.widx[w] = Some(tr.spans.len());
                        tr.spans.push(Span {
                            task: reqs[ri].id,
                            stage: Some(0),
                            attempt: *gen,
                            kind,
                            start_ms: now,
                            end_ms: now + proc_ms,
                            node: Some(w),
                            y: 0,
                            cancelled: false,
                        });
                    }
                }
            }
        }

        while let Some(Reverse(t)) = heap.pop() {
            let now = t.at_ms;
            horizon = horizon.max(now);
            match t.ev {
                Ev::Arrive(i) => {
                    if queue.len() >= self.cfg.queue_capacity {
                        // Graceful degradation: shed the NEW admission.
                        stats.shed += 1;
                    } else {
                        accepted += 1;
                        queue.push_back(i);
                    }
                }
                Ev::Net(i) => {
                    let o = self.outages[i];
                    let w = &mut workers[o.worker];
                    if !o.up {
                        w.down += 1;
                        if w.down == 1 {
                            if let Some((ri, _)) = w.serving.take() {
                                // The in-flight attempt dies with the
                                // worker: truncate its span at the outage.
                                if let Some(tr) = tr.as_mut() {
                                    if let Some(si) = tr.widx[o.worker].take() {
                                        tr.spans[si].end_ms = now;
                                        tr.spans[si].cancelled = true;
                                    }
                                }
                                // In-flight on a dying worker: re-route,
                                // not drop. Backoff before re-dispatch.
                                let r = &mut reqs[ri];
                                if !r.completed {
                                    r.attempts += 1;
                                    r.rerouted = true;
                                    stats.retries += 1;
                                    if retry.exhausted(r.attempts) && !r.exhausted_counted {
                                        r.exhausted_counted = true;
                                        stats.retry_exhausted += 1;
                                    }
                                    let back = retry.backoff_ms(r.attempts, r.id);
                                    push(&mut heap, &mut seq, now + back, Ev::Wake(ri));
                                    if let Some(tr) = tr.as_mut() {
                                        tr.spans.push(Span {
                                            task: r.id,
                                            stage: Some(0),
                                            attempt: r.attempts as u64,
                                            kind: SpanKind::Backoff,
                                            start_ms: now,
                                            end_ms: now + back,
                                            node: None,
                                            y: 0,
                                            cancelled: false,
                                        });
                                        tr.wait_since[ri] = now + back;
                                    }
                                }
                            }
                        }
                    } else {
                        w.down = w.down.saturating_sub(1);
                        if w.down == 0 {
                            // Restart clock: checkpointed restore vs cold
                            // start (mirrors `CoreRouter::rejoin`).
                            if checkpoint.enabled() {
                                stats.checkpoint_restores += 1;
                                w.free_at = now + checkpoint.restore_ms;
                            } else {
                                w.free_at = now + checkpoint.cold_start_ms;
                            }
                            let at = w.free_at;
                            // A Wake with no request re-enqueues nothing
                            // but triggers a dispatch pass: reuse the
                            // sentinel usize::MAX.
                            push(&mut heap, &mut seq, at, Ev::Wake(usize::MAX));
                        }
                    }
                }
                Ev::Done(w, g) => {
                    let matched = workers[w].serving.map_or(false, |(_, cur)| cur == g);
                    if matched {
                        let (ri, _) = workers[w].serving.take().unwrap();
                        if let Some(tr) = tr.as_mut() {
                            if let Some(si) = tr.widx[w].take() {
                                // A hedge partner that lost the race did
                                // run to completion, but its result is
                                // discarded: mark the span cancelled.
                                if reqs[ri].completed {
                                    tr.spans[si].cancelled = true;
                                }
                            }
                        }
                        let r = &mut reqs[ri];
                        if !r.completed {
                            r.completed = true;
                            served += 1;
                            let lat = now - r.arrive_ms;
                            latencies.push(lat);
                            if lat <= r.deadline_ms {
                                on_time += 1;
                            }
                            if r.rerouted {
                                stats.reroutes += 1;
                            }
                        }
                        // else: the hedge partner won — just free up.
                    }
                }
                Ev::Wake(ri) => {
                    if ri != usize::MAX && !reqs[ri].completed {
                        queue.push_back(ri);
                        if let Some(tr) = tr.as_mut() {
                            tr.wait_since[ri] = now;
                        }
                    }
                }
            }
            dispatch(
                now,
                &mut queue,
                &mut reqs,
                &mut workers,
                &mut heap,
                &mut seq,
                &mut gen,
                &mut stats,
                &retry,
                self.cfg.proc_ms,
                &mut tr,
            );
            // Drain-phase fast-forward: if nothing is scheduled but
            // accepted work remains (every worker down past the last
            // recovery event), force-recover the pool so accepted work is
            // served, never abandoned.
            if heap.is_empty() && !queue.is_empty() {
                for w in workers.iter_mut() {
                    w.down = 0;
                    w.free_at = now;
                }
                dispatch(
                    now,
                    &mut queue,
                    &mut reqs,
                    &mut workers,
                    &mut heap,
                    &mut seq,
                    &mut gen,
                    &mut stats,
                    &retry,
                    self.cfg.proc_ms,
                    &mut tr,
                );
            }
        }

        if let (Some(r), Some(tr)) = (rec.as_deref_mut(), tr) {
            for s in tr.spans {
                r.push_raw(s);
            }
        }

        stats.abandoned = accepted - served;
        ReplayReport {
            accepted,
            served,
            on_time,
            latencies_ms: latencies,
            horizon_ms: horizon,
            stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_capped_and_jitter_is_deterministic() {
        let p = RetryPolicy::default();
        let b1 = p.backoff_ms(1, 42);
        let b2 = p.backoff_ms(2, 42);
        let b9 = p.backoff_ms(9, 42);
        assert!(b1 > 0.0);
        assert!(b2 > b1 * 1.2, "second retry backs off further: {b1} -> {b2}");
        assert!(b9 <= p.max_backoff_ms, "capped at the ceiling");
        assert_eq!(p.backoff_ms(3, 7), p.backoff_ms(3, 7), "deterministic");
        assert_ne!(p.backoff_ms(3, 7), p.backoff_ms(3, 8), "keyed by id");
        assert!(p.exhausted(p.max_attempts + 1));
        assert!(!p.exhausted(p.max_attempts));
    }

    #[test]
    fn hedge_fires_only_near_deadline() {
        let p = RetryPolicy::default();
        assert!(!p.should_hedge(50.0, 100.0));
        assert!(p.should_hedge(10.0, 100.0));
        let off = RetryPolicy { hedge_slack_frac: 0.0, ..p };
        assert!(!off.should_hedge(1.0, 100.0));
    }

    #[test]
    fn attempt_timeout_tracks_g_budget() {
        let p = RetryPolicy::default();
        assert!((p.attempt_timeout_ms(100.0, 10.0) - 15.0).abs() < 1e-12);
        assert!((p.attempt_timeout_ms(12.0, 10.0) - 12.0).abs() < 1e-12, "deadline-capped");
    }

    #[test]
    fn spec_parser_builds_paired_outages() {
        let s = parse_fault_spec("zone@100+50", 10, 6).unwrap();
        // half of 6 ESs = 3 nodes, down + up each.
        assert_eq!(s.len(), 6);
        assert!(matches!(
            s.events()[0].kind,
            FaultKind::NodeDown { node } if node >= 10
        ));
        let s1 = parse_fault_spec("es2@10+5", 10, 6).unwrap();
        assert_eq!(s1.len(), 2);
        assert!(matches!(s1.events()[0].kind, FaultKind::NodeDown { node: 12 }));
        assert!(parse_fault_spec("es9@10+5", 10, 6).is_err());
        assert!(parse_fault_spec("zone@10", 10, 6).is_err());
        assert!(parse_fault_spec("bogus@1+1", 10, 6).is_err());
        assert!(parse_fault_spec("", 10, 6).is_err());
    }

    #[test]
    fn worker_compiler_maps_es_nodes_and_synthesizes_restarts() {
        let sched = FaultSchedule::from_events(vec![
            FaultEvent { time_ms: 10.0, kind: FaultKind::NodeDown { node: 10 } },
            FaultEvent { time_ms: 20.0, kind: FaultKind::NodeUp { node: 10 } },
            FaultEvent {
                time_ms: 15.0,
                kind: FaultKind::CoreReplicaFail { node: 11, core_idx: 0 },
            },
            FaultEvent { time_ms: 5.0, kind: FaultKind::LinkDown { link: 0 } },
            FaultEvent { time_ms: 6.0, kind: FaultKind::NodeDown { node: 3 } }, // ED: ignored
        ]);
        let cp = CheckpointConfig::default();
        let evs = compile_worker_events(&sched, 2, 10, &cp);
        // node 10 -> worker 0 (down+up), replica fail at 11 -> worker 1
        // down + synthesized up after restore_ms.
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0], WorkerEvent { at_ms: 10.0, worker: 0, up: false });
        assert!(evs
            .iter()
            .any(|e| e.worker == 1 && !e.up && (e.at_ms - 15.0).abs() < 1e-12));
        assert!(evs
            .iter()
            .any(|e| e.worker == 1 && e.up && (e.at_ms - (15.0 + cp.restore_ms)).abs() < 1e-12));
    }

    fn open_loop(n: usize, gap_ms: f64, deadline_ms: f64) -> Vec<VirtualRequest> {
        (0..n)
            .map(|i| VirtualRequest {
                id: i as u64,
                arrive_ms: i as f64 * gap_ms,
                deadline_ms,
            })
            .collect()
    }

    #[test]
    fn fault_free_replay_serves_everything_on_time() {
        let cfg = ReplayConfig { workers: 2, proc_ms: 1.0, ..Default::default() };
        let server = ReplayServer::new(cfg, &FaultSchedule::none(), 10);
        let r = server.run(&open_loop(100, 1.0, 50.0));
        assert_eq!(r.accepted, 100);
        assert_eq!(r.served, 100);
        assert_eq!(r.on_time, 100);
        assert_eq!(r.stats, FailoverStats::default());
    }

    #[test]
    fn outage_reroutes_in_flight_work_and_abandons_nothing() {
        let sched = parse_fault_spec("es0@20+100", 10, 4).unwrap();
        let cfg = ReplayConfig { workers: 2, proc_ms: 5.0, ..Default::default() };
        let server = ReplayServer::new(cfg, &sched, 10);
        let r = server.run(&open_loop(200, 1.0, 40.0));
        assert_eq!(r.accepted, 200);
        assert_eq!(r.served, 200, "every accepted request is served");
        assert_eq!(r.stats.abandoned, 0);
        assert!(r.stats.retries > 0, "in-flight work on the dying worker retried");
        assert!(r.stats.reroutes > 0, "retried work completes elsewhere");
        assert!(r.on_time < r.served, "a long outage costs some deadlines");
    }

    #[test]
    fn replay_is_bit_deterministic() {
        let sched = parse_fault_spec("zone@30+60,es1@150+40", 10, 4).unwrap();
        let cfg = ReplayConfig { workers: 3, proc_ms: 2.5, ..Default::default() };
        let server = ReplayServer::new(cfg, &sched, 10);
        let arr = open_loop(500, 0.7, 30.0);
        let a = server.run(&arr);
        let b = server.run(&arr);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.served, b.served);
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.latencies_ms, b.latencies_ms);
    }

    #[test]
    fn saturation_sheds_new_admissions_first() {
        let sched = parse_fault_spec("zone@0+500", 10, 2).unwrap();
        let cfg = ReplayConfig {
            workers: 1,
            queue_capacity: 8,
            proc_ms: 10.0,
            ..Default::default()
        };
        let server = ReplayServer::new(cfg, &sched, 10);
        let r = server.run(&open_loop(100, 1.0, 50.0));
        assert!(r.stats.shed > 0, "overload under outage sheds new work");
        assert_eq!(r.accepted, r.served, "accepted work is never abandoned");
        assert_eq!(r.stats.abandoned, 0);
    }
}
