//! Streaming trial statistics: Welford accumulation with exact merging
//! and Student-t 95% confidence intervals.
//!
//! The sweep orchestrator aggregates per-trial metrics without keeping
//! the raw samples: one [`Welford`] per reported column. Accumulators are
//! mergeable (Chan et al.'s pairwise update), so partial aggregates
//! computed anywhere can be combined without changing the result — the
//! same property [`crate::metrics::Histogram::merge`] gives the latency
//! distributions.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

/// Two-sided 97.5% Student-t critical values for 1..=30 degrees of
/// freedom; larger dof use the normal approximation.
const T_975: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179, 2.160,
    2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056,
    2.052, 2.048, 2.045, 2.042,
];

/// t-critical value for a 95% CI at `dof` degrees of freedom.
pub fn t_critical_95(dof: u64) -> f64 {
    match dof {
        0 => f64::INFINITY,
        d if d <= 30 => T_975[(d - 1) as usize],
        _ => 1.96,
    }
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Merge another accumulator (Chan et al.): the result is exactly the
    /// accumulator of the concatenated sample, up to float rounding.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator); 0 for fewer than two samples.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            // m2 can go infinitesimally negative through float rounding.
            (self.m2 / (self.n - 1) as f64).max(0.0)
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Half-width of the 95% confidence interval on the mean (Student-t);
    /// 0 for fewer than two samples.
    pub fn ci95_half(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        t_critical_95(self.n - 1) * (self.var() / self.n as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_direct_mean_and_variance() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // Sample variance of this classic set is 32/7.
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_pooled_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin() * 3.0 + 1.0).collect();
        let mut pooled = Welford::new();
        for &x in &xs {
            pooled.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..33] {
            a.push(x);
        }
        for &x in &xs[33..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), pooled.count());
        assert!((a.mean() - pooled.mean()).abs() < 1e-12);
        assert!((a.var() - pooled.var()).abs() < 1e-9);
        // Merging an empty accumulator changes nothing, either way.
        let mut c = Welford::new();
        c.merge(&pooled);
        assert!((c.mean() - pooled.mean()).abs() < 1e-12);
        pooled.merge(&Welford::new());
        assert!((pooled.mean() - c.mean()).abs() < 1e-12);
    }

    #[test]
    fn ci_is_zero_then_shrinks() {
        let mut w = Welford::new();
        w.push(1.0);
        assert_eq!(w.ci95_half(), 0.0, "one sample: no interval");
        w.push(3.0);
        let wide = w.ci95_half();
        assert!(wide > 0.0);
        // More samples at the same spread tighten the interval.
        for _ in 0..50 {
            w.push(1.0);
            w.push(3.0);
        }
        assert!(w.ci95_half() < wide / 3.0);
    }

    #[test]
    fn t_table_edges() {
        assert_eq!(t_critical_95(0), f64::INFINITY);
        assert!((t_critical_95(1) - 12.706).abs() < 1e-9);
        assert!((t_critical_95(30) - 2.042).abs() < 1e-9);
        assert!((t_critical_95(1000) - 1.96).abs() < 1e-9);
    }
}
