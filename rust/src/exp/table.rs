//! Result tables: the sweep's artifact format.
//!
//! A [`Table`] is the deterministic, schedule-independent product of a
//! sweep: rows are assembled in grid order whatever the thread count, and
//! every value is pre-formatted text, so "parallel equals serial" can be
//! asserted byte-for-byte on [`Table::to_csv`]. Writers cover CSV (the
//! CI artifact) and JSON (machine consumption); [`Table::validate`] is
//! the NaN/empty gate the `fmedge sweep` command enforces before writing
//! anything.

/// A named result table with a fixed column schema.
#[derive(Clone, Debug, PartialEq)]
pub struct Table {
    pub name: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(name: &str, headers: &[&str]) -> Self {
        Table {
            name: name.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity — checked by
    /// [`Table::validate`], not here, so partially-built tables can be
    /// inspected).
    pub fn push_row(&mut self, row: Vec<String>) {
        self.rows.push(row);
    }

    /// Append a row of numeric values, formatted to round-trip telemetry:
    /// integral values print without a fraction (counters stay greppable),
    /// everything else gets six decimals with trailing zeros trimmed.
    /// Non-finite values pass through as `NaN`/`inf` text so
    /// [`Table::validate`] still catches them.
    pub fn push_numeric_row(&mut self, values: &[f64]) {
        self.rows.push(values.iter().map(|&v| fmt_numeric(v)).collect());
    }

    /// Well-formedness gate: every row matches the header arity, no cell
    /// is empty, and no numeric cell is NaN/inf. A sweep whose table
    /// fails this must not publish artifacts — an empty or NaN cell means
    /// a grid point silently produced garbage.
    pub fn validate(&self) -> Result<(), String> {
        if self.rows.is_empty() {
            return Err(format!("table `{}` has no rows", self.name));
        }
        for (i, row) in self.rows.iter().enumerate() {
            if row.len() != self.headers.len() {
                return Err(format!(
                    "table `{}` row {i}: {} cells, expected {}",
                    self.name,
                    row.len(),
                    self.headers.len()
                ));
            }
            for (j, cell) in row.iter().enumerate() {
                if cell.trim().is_empty() {
                    return Err(format!(
                        "table `{}` row {i} column `{}`: empty cell",
                        self.name, self.headers[j]
                    ));
                }
                // Parse-based non-finite gate: any cell that reads as an
                // f64 must be finite. The previous substring match on
                // "nan"/"inf" missed overflow spellings like `1e999`
                // (which parse to +inf) and rejected legitimate text
                // cells that merely contain those letters.
                if let Ok(x) = cell.parse::<f64>() {
                    if !x.is_finite() {
                        return Err(format!(
                            "table `{}` row {i} column `{}`: non-finite value `{cell}`",
                            self.name, self.headers[j]
                        ));
                    }
                }
                if cell.contains(',') || cell.contains('\n') {
                    return Err(format!(
                        "table `{}` row {i} column `{}`: `{cell}` would corrupt CSV",
                        self.name, self.headers[j]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Plain CSV (no quoting — [`Table::validate`] rejects cells that
    /// would need it).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }

    /// JSON array of objects, all values as strings.
    pub fn to_json(&self) -> String {
        let esc = |v: &str| v.replace('\\', "\\\\").replace('"', "\\\"");
        let mut s = String::from("[\n");
        for (i, row) in self.rows.iter().enumerate() {
            s.push_str("  {");
            for (j, (h, v)) in self.headers.iter().zip(row).enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("\"{}\": \"{}\"", esc(h), esc(v)));
            }
            s.push('}');
            if i + 1 < self.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push(']');
        s
    }

    /// Column-aligned text for terminal reports.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (j, cell) in row.iter().enumerate() {
                if j < widths.len() {
                    widths[j] = widths[j].max(cell.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (j, cell) in cells.iter().enumerate() {
                let w = widths.get(j).copied().unwrap_or(cell.len());
                line.push_str(&format!("{cell:<w$}"));
                if j + 1 < cells.len() {
                    line.push_str("  ");
                }
            }
            line.trim_end().to_string()
        };
        let mut out = format!("== {} ==\n", self.name);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Write the CSV artifact.
    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }

    /// Write the JSON artifact.
    pub fn save_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Compact numeric cell formatting for [`Table::push_numeric_row`].
fn fmt_numeric(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    if v.fract() == 0.0 && v.abs() < 1e15 {
        return format!("{}", v as i64);
    }
    let s = format!("{v:.6}");
    let s = s.trim_end_matches('0').trim_end_matches('.');
    s.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push_row(vec!["1".into(), "2.50".into()]);
        t.push_row(vec!["x".into(), "0.000001".into()]);
        t
    }

    #[test]
    fn csv_roundtrip_shape() {
        let t = sample();
        assert!(t.validate().is_ok());
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,2.50\nx,0.000001\n");
    }

    #[test]
    fn json_is_an_object_array() {
        let j = sample().to_json();
        assert!(j.starts_with('['));
        assert!(j.contains("\"a\": \"1\""));
        assert!(j.trim_end().ends_with(']'));
    }

    #[test]
    fn validate_catches_nan_empty_and_arity() {
        let mut t = sample();
        t.push_row(vec!["NaN".into(), "3".into()]);
        assert!(t.validate().unwrap_err().contains("non-finite"));
        // ±Inf in every spelling Rust's float parser accepts, plus the
        // overflow form the old substring check let through.
        for bad in ["inf", "-inf", "Infinity", "-Infinity", "1e999", "-1e999"] {
            let mut t = sample();
            t.push_row(vec![bad.into(), "3".into()]);
            assert!(
                t.validate().unwrap_err().contains("non-finite"),
                "`{bad}` must be rejected"
            );
        }
        // Text cells that merely contain the letters are fine.
        let mut t = sample();
        t.push_row(vec!["infra-scenario".into(), "3".into()]);
        assert!(t.validate().is_ok());
        let mut t = sample();
        t.push_row(vec!["".into(), "3".into()]);
        assert!(t.validate().unwrap_err().contains("empty cell"));
        let mut t = sample();
        t.push_row(vec!["only-one".into()]);
        assert!(t.validate().unwrap_err().contains("expected 2"));
        let t = Table::new("hollow", &["a"]);
        assert!(t.validate().unwrap_err().contains("no rows"));
        let mut t = sample();
        t.push_row(vec!["a,b".into(), "3".into()]);
        assert!(t.validate().unwrap_err().contains("corrupt CSV"));
    }

    #[test]
    fn render_aligns_columns() {
        let r = sample().render();
        assert!(r.contains("== demo =="));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn numeric_rows_format_compactly() {
        let mut t = Table::new("telemetry", &["time_ms", "count", "gauge"]);
        t.push_numeric_row(&[10.0, 4.0, 2.5]);
        t.push_numeric_row(&[20.5, 5.0, 0.000001]);
        assert!(t.validate().is_ok());
        assert_eq!(t.rows[0], vec!["10", "4", "2.5"]);
        assert_eq!(t.rows[1], vec!["20.5", "5", "0.000001"]);
        // Non-finite values stay visible so validate() can reject them.
        let mut bad = Table::new("bad", &["x"]);
        bad.push_numeric_row(&[f64::NAN]);
        assert!(bad.validate().is_err());
    }
}
