//! Experiment orchestration: seeded sweep grids, parallel execution, and
//! artifact writers — `fmedge sweep` turns the EXPERIMENTS.md fill-in
//! tables into one command.
//!
//! * [`runner::run_cells`] — scoped worker threads over grid cells; every
//!   cell derives all of its randomness statelessly from
//!   `(sweep_seed, grid coordinates, trial)` via [`stream_seed`], so the
//!   output is **bit-identical for any `--threads`** (asserted in
//!   `rust/tests/sweep.rs`).
//! * [`stats::Welford`] — streaming mean/CI95 per reported column. The
//!   orchestrator itself aggregates each cell's trials inline in the
//!   owning worker (no cross-worker merging happens here); the exact
//!   [`Welford::merge`] / [`Histogram::merge`] methods exist for pooling
//!   partial aggregates across *separate runs* and are exercised in
//!   tests.
//! * [`table::Table`] — CSV/JSON artifact writers plus the NaN/empty-cell
//!   gate CI enforces.
//!
//! Experiments ([`Experiment`]):
//! * `p1b` — exact-placement node-LP A/B (dense rebuild vs warm revised
//!   simplex) per seed. The `solve_ms` column is wall-clock and therefore
//!   excluded from the bit-identity guarantee (it varies run to run even
//!   serially); all solution columns are deterministic.
//! * `p2`  — measured-vs-analytic bound validation: paired slotted + DES
//!   runs per ε, pooled per-service violation rates.
//! * `p4`  — fault-injection robustness grid
//!   (engine × load × strategy × failure rate), with the retained-vs-rate-0
//!   fraction computed per strategy.
//! * `p5`  — scenario-robustness ensemble over the
//!   [`crate::scenarios`] library (non-stationary arrivals, ED churn,
//!   correlated outages) under both engines.
//! * `p10` — elastic autoscaling vs fixed-parallelism A/B
//!   ([`crate::pool`]): paired traces across diurnal + flash-crowd
//!   scenarios and load multipliers, both engines, reporting on-time
//!   rate against deployment cost (replica-slot-seconds, cold starts,
//!   pool-size p95).

mod runner;
mod stats;
mod table;

pub use runner::{run_cells, run_grid2};
pub use stats::{t_critical_95, Welford};
pub use table::Table;

use crate::baselines::{GaStrategy, LbrrStrategy, PropAvg, Proposal};
use crate::config::ExperimentConfig;
use crate::des::{
    pool, run_des_trial, run_des_trial_faulted, run_des_trial_faulted_in, validate_bounds,
    DesArena, DesOptions,
};
use crate::faults::{FaultParams, FaultSchedule};
use crate::ilp::NodeLpMode;
use crate::metrics::Histogram;
use crate::placement::{solve_static_placement, PlacementParams, QosScores, ScoreParams};
use crate::rng::{stream_seed, Xoshiro256};
use crate::scenarios::{CompiledScenario, ScenarioSpec};
use crate::sim::{record_trace, run_trial_faulted, run_trial_traced, SimEnv, SimOptions, Strategy};
use crate::workload::{Trace, WorkloadGenerator};

/// Which EXPERIMENTS.md grid to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Experiment {
    P1b,
    P2,
    P4,
    P5,
    P10,
}

impl Experiment {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "p1b" => Ok(Experiment::P1b),
            "p2" => Ok(Experiment::P2),
            "p4" => Ok(Experiment::P4),
            "p5" => Ok(Experiment::P5),
            "p10" => Ok(Experiment::P10),
            other => Err(format!("unknown experiment `{other}` (p1b|p2|p4|p5|p10)")),
        }
    }

    /// Grid axes this experiment does NOT consume (lives next to the
    /// `sweep_*` implementations so it can't drift from them — the CLI
    /// warns rather than silently dropping an explicitly passed axis).
    pub fn ignored_axes(self) -> &'static [&'static str] {
        match self {
            Experiment::P1b => &[
                "loads",
                "rates",
                "epsilons",
                "strategies",
                "engines",
                "scenarios",
                "slots",
            ],
            Experiment::P2 => &["loads", "rates", "strategies", "engines", "scenarios"],
            Experiment::P4 => &["epsilons", "scenarios"],
            Experiment::P5 => &["loads", "rates", "epsilons"],
            // p10 hardcodes its autoscale-vs-fixed mode pair (the A/B is
            // the experiment), so the strategy axis is not consumed.
            Experiment::P10 => &["rates", "epsilons", "strategies"],
        }
    }
}

/// Simulation engine selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Engine {
    Slotted,
    Des,
}

impl Engine {
    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "slotted" => Ok(Engine::Slotted),
            "des" => Ok(Engine::Des),
            other => Err(format!("unknown engine `{other}` (slotted|des)")),
        }
    }

    fn name(self) -> &'static str {
        match self {
            Engine::Slotted => "slotted",
            Engine::Des => "des",
        }
    }
}

/// Instantiate a deployment strategy by its CLI name.
pub fn strategy_by_name(name: &str) -> Result<Box<dyn Strategy>, String> {
    Ok(match name {
        "proposal" => Box::new(Proposal::new()),
        "propavg" => Box::new(PropAvg::new()),
        "lbrr" => Box::new(LbrrStrategy::new()),
        "ga" => Box::new(GaStrategy::new(16, 12)),
        // Pool-aware: per-instance y is pinned to 1 so parallelism comes
        // from replica counts (crate::pool, §P10), not planned splits.
        "autoscale" => Box::new(crate::pool::Autoscale::new()),
        other => return Err(format!("unknown strategy `{other}`")),
    })
}

/// Sweep parameters (grid axes default per experiment; see
/// [`SweepConfig::for_experiment`]).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub experiment: Experiment,
    /// Trials (p1b: solver instances) per grid cell.
    pub trials: usize,
    /// Horizon per trial, in slots.
    pub slots: usize,
    /// Root seed every per-cell/per-trial stream derives from.
    pub seed: u64,
    /// Worker threads (1 = the reference serial order).
    pub threads: usize,
    pub loads: Vec<f64>,
    pub rates: Vec<f64>,
    pub strategies: Vec<String>,
    pub engines: Vec<String>,
    /// p5: library scenario names (empty = full library).
    pub scenarios: Vec<String>,
    /// p2: ε targets.
    pub epsilons: Vec<f64>,
}

impl SweepConfig {
    /// The EXPERIMENTS.md grid for `experiment`.
    pub fn for_experiment(experiment: Experiment) -> Self {
        let base = SweepConfig {
            experiment,
            trials: 3,
            slots: 200,
            seed: 7,
            threads: 1,
            loads: vec![1.0, 2.0],
            rates: vec![0.0, 0.002, 0.01],
            strategies: vec!["proposal".into(), "lbrr".into(), "ga".into()],
            engines: vec!["slotted".into(), "des".into()],
            scenarios: Vec::new(),
            epsilons: vec![0.05, 0.2],
        };
        match experiment {
            Experiment::P1b => SweepConfig {
                trials: 5,
                ..base
            },
            Experiment::P2 => SweepConfig {
                slots: 300,
                strategies: vec!["proposal".into()],
                ..base
            },
            Experiment::P4 => base,
            // 400 slots -> arrivals run to slot 250, long enough for a
            // full diurnal cycle, the flash crowd, and the commuter /
            // rush-hour flips (at slots 60/100+) to land inside the
            // arrival window rather than in the drain tail.
            Experiment::P5 => SweepConfig {
                slots: 400,
                strategies: vec!["proposal".into()],
                ..base
            },
            // 400 slots for the same reason as p5: the diurnal cycle and
            // the flash crowd must land inside the arrival window so the
            // pool actually has peaks to chase and troughs to drain in.
            Experiment::P10 => SweepConfig {
                slots: 400,
                scenarios: vec!["diurnal".into(), "flash-crowd".into()],
                ..base
            },
        }
    }
}

/// Stream tags (see [`stream_seed`]): the `stream` coordinate combines a
/// per-purpose tag with the *values* of the grid axes a fixture depends
/// on (load bits, rate bits, ε bits, scenario-name hash) — never with
/// axis indices. Paired cells (same trace/schedule, different strategy
/// or engine) therefore derive identical fixtures, distinct fixtures
/// stay independent, and a named cell realizes the same trace/schedule
/// whatever other axis entries the grid happens to contain (so a single
/// row can be re-run in isolation and reproduced exactly).
const TAG_P1B: u64 = 0x1B00;
const TAG_P2: u64 = 0x2000;
const TAG_P4_FIXTURE: u64 = 0x4000;
const TAG_P4_SCHEDULE: u64 = 0x4500;
const TAG_P5_ENV: u64 = 0x5000;
const TAG_P5_SCENARIO: u64 = 0x5100;
const TAG_P10_ENV: u64 = 0xA000;
const TAG_P10_SCENARIO: u64 = 0xA100;

/// Tag-seeded FNV-1a fold: one definition so value-keyed and name-keyed
/// stream coordinates cannot drift apart.
fn fnv_stream(tag: u64, bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ tag;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Stream coordinate for a numeric axis *value* (load/rate/ε bits).
fn axis_stream(tag: u64, value_bits: u64) -> u64 {
    fnv_stream(tag, &value_bits.to_le_bytes())
}

/// Stream coordinate for a named axis entry (scenarios).
fn name_stream(tag: u64, name: &str) -> u64 {
    fnv_stream(tag, name.as_bytes())
}

/// Run the configured sweep and return its result table. Grid axes are
/// validated up front; cells then run (possibly in parallel) and rows are
/// assembled in grid order.
pub fn run_sweep(base: &ExperimentConfig, sc: &SweepConfig) -> Result<Table, String> {
    if sc.trials == 0 {
        return Err("need at least one trial per cell".into());
    }
    // Rust's float parser accepts "nan"/"inf" and nothing downstream
    // rejects a negative rate or an out-of-range ε until a worker panics
    // deep inside SimEnv::build — validate the axes up front instead.
    for (axis, vals) in [
        ("loads", &sc.loads),
        ("rates", &sc.rates),
        ("epsilons", &sc.epsilons),
    ] {
        if let Some(bad) = vals.iter().find(|x| !x.is_finite() || **x < 0.0) {
            return Err(format!(
                "--{axis} contains an invalid value `{bad}` (need finite and >= 0)"
            ));
        }
    }
    if let Some(bad) = sc.epsilons.iter().find(|e| **e <= 0.0 || **e >= 1.0) {
        return Err(format!("--epsilons must lie in (0, 1), got `{bad}`"));
    }
    match sc.experiment {
        Experiment::P1b => sweep_p1b(base, sc),
        Experiment::P2 => sweep_p2(base, sc),
        Experiment::P4 => sweep_p4(base, sc),
        Experiment::P5 => sweep_p5(base, sc),
        Experiment::P10 => sweep_p10(base, sc),
    }
}

fn f6(x: f64) -> String {
    format!("{x:.6}")
}

// ---------------------------------------------------------------------
// p1b — exact placement node-LP A/B (dense rebuild vs warm revised)
// ---------------------------------------------------------------------

fn sweep_p1b(base: &ExperimentConfig, sc: &SweepConfig) -> Result<Table, String> {
    let modes = [
        ("dense-rebuild", NodeLpMode::DenseRebuild),
        ("warm-revised", NodeLpMode::WarmRevised),
    ];
    let cells: Vec<(usize, usize)> = (0..modes.len())
        .flat_map(|m| (0..sc.trials).map(move |t| (m, t)))
        .collect();
    // The (env, scores) fixture depends only on the trial — build it
    // once and share it across both mode cells (SimEnv::build includes
    // the expensive g-table sampling; the A/B only varies the node-LP
    // engine of the solve).
    struct Fixture {
        env: SimEnv,
        scores: QosScores,
    }
    let fixtures = run_cells(sc.trials, sc.threads, |trial| {
        let fseed = stream_seed(sc.seed, TAG_P1B, trial as u64);
        let env = SimEnv::build(base, fseed);
        let gen = WorkloadGenerator::new(
            base,
            &env.app,
            &env.topo,
            &mut Xoshiro256::seed_from(env.users_seed),
        );
        let scores = QosScores::compute(
            &env.app,
            &env.topo,
            &env.dm,
            gen.users(),
            &ScoreParams::from_config(&base.controller),
        );
        Fixture { env, scores }
    });
    let results = run_cells(cells.len(), sc.threads, |i| {
        let (mi, trial) = cells[i];
        let fx = &fixtures[trial];
        let mut params = PlacementParams::from_config(base, base.sim.slots);
        params.exact = true;
        params.node_lp = modes[mi].1;
        let t0 = std::time::Instant::now();
        let sol = solve_static_placement(&fx.env.app, &fx.env.topo, &fx.scores, &params);
        let dt = t0.elapsed();
        vec![
            modes[mi].0.to_string(),
            trial.to_string(),
            format!("{:.3}", sol.objective),
            sol.total_instances().to_string(),
            sol.support.to_string(),
            format!("{:.3}", dt.as_secs_f64() * 1e3),
        ]
    });
    let mut table = Table::new(
        "p1b — exact placement: dense-rebuild vs warm-revised node LPs",
        &["mode", "instance", "objective", "instances", "support", "solve_ms"],
    );
    for row in results {
        table.push_row(row);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// p2 — measured-vs-analytic bound validation (paired slotted + DES)
// ---------------------------------------------------------------------

fn sweep_p2(base: &ExperimentConfig, sc: &SweepConfig) -> Result<Table, String> {
    // Parallelize over (epsilon, trial) — the paired slotted+DES runs
    // are the expensive part, and each has its own stateless stream, so
    // flattening keeps bit-identity while actually using the workers
    // (per-epsilon cells alone would cap concurrency at the handful of
    // ε targets). Per-epsilon aggregation below is exact merging.
    struct TrialOut {
        vals: Vec<crate::des::ServiceValidation>,
        slotted: f64,
        des: f64,
    }
    let groups = run_grid2(sc.epsilons.len(), sc.trials, sc.threads, |ei, trial| {
        let mut cfg = base.clone();
        cfg.sim.slots = sc.slots;
        cfg.controller.epsilon = sc.epsilons[ei];
        let fseed = stream_seed(
            sc.seed,
            axis_stream(TAG_P2, sc.epsilons[ei].to_bits()),
            trial as u64,
        );
        let env = SimEnv::build(&cfg, fseed);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, fseed, &opts);
        let s = run_trial_traced(&env, &mut Proposal::new(), fseed, &opts, &trace);
        let d = run_des_trial(
            &env,
            &mut Proposal::new(),
            fseed,
            &DesOptions::from_sim(&opts),
            &trace,
        );
        TrialOut {
            vals: validate_bounds(&env.gtable, &d),
            slotted: s.on_time_rate(),
            des: d.on_time_rate(),
        }
    });

    struct Cell {
        services: usize,
        holding: usize,
        worst_rate: f64,
        slotted: Welford,
        des: Welford,
    }
    let results: Vec<Cell> = groups
        .iter()
        .map(|group| {
            let vals: Vec<Vec<crate::des::ServiceValidation>> =
                group.iter().map(|t| t.vals.clone()).collect();
            let pooled = pool(&vals);
            // Zero-sample services are trivially holding (violation rate
            // 0), so holds() alone covers them.
            let holding = pooled.iter().filter(|v| v.holds(0.05)).count();
            let worst = pooled
                .iter()
                .map(|v| v.violation_rate())
                .fold(0.0f64, f64::max);
            let mut slotted_w = Welford::new();
            let mut des_w = Welford::new();
            for t in group {
                slotted_w.push(t.slotted);
                des_w.push(t.des);
            }
            Cell {
                services: pooled.len(),
                holding,
                worst_rate: worst,
                slotted: slotted_w,
                des: des_w,
            }
        })
        .collect();
    let mut table = Table::new(
        "p2 — measured-vs-analytic delay bounds (paired engines)",
        &[
            "epsilon",
            "trials",
            "services",
            "holding",
            "worst_rate",
            "slotted_on_time",
            "slotted_ci95",
            "des_on_time",
            "des_ci95",
        ],
    );
    for (ei, c) in results.into_iter().enumerate() {
        table.push_row(vec![
            format!("{:.3}", sc.epsilons[ei]),
            sc.trials.to_string(),
            c.services.to_string(),
            c.holding.to_string(),
            f6(c.worst_rate),
            f6(c.slotted.mean()),
            f6(c.slotted.ci95_half()),
            f6(c.des.mean()),
            f6(c.des.ci95_half()),
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// p4 — fault-injection robustness grid
// ---------------------------------------------------------------------

fn sweep_p4(base: &ExperimentConfig, sc: &SweepConfig) -> Result<Table, String> {
    let engines: Vec<Engine> = sc
        .engines
        .iter()
        .map(|e| Engine::parse(e))
        .collect::<Result<_, _>>()?;
    for s in &sc.strategies {
        strategy_by_name(s)?; // validate names before spawning workers
    }
    let mut rates = sc.rates.clone();
    rates.sort_by(f64::total_cmp);

    // Grid order (also row order): engine, load, strategy, rate.
    let mut cells = Vec::new();
    for ei in 0..engines.len() {
        for li in 0..sc.loads.len() {
            for si in 0..sc.strategies.len() {
                for ri in 0..rates.len() {
                    cells.push((ei, li, si, ri));
                }
            }
        }
    }
    // Fixture (env + trace) is keyed by (load, trial) only, so every
    // engine, strategy, and rate replays the same realized workload —
    // the §P4 pairing. Build each fixture once and share it by reference
    // across cells instead of rebuilding it in all of them; the builds
    // themselves go through `run_cells` (SimEnv::build includes the
    // expensive g-table sampling, and the seeds are stateless, so
    // building in parallel changes nothing).
    struct Fixture {
        seed: u64,
        env: SimEnv,
        opts: SimOptions,
        trace: Trace,
    }
    let fixtures = run_grid2(sc.loads.len(), sc.trials, sc.threads, |li, trial| {
        let mut cfg = base.clone();
        cfg.sim.slots = sc.slots;
        cfg.sim.load_multiplier = sc.loads[li];
        let fseed = stream_seed(
            sc.seed,
            axis_stream(TAG_P4_FIXTURE, sc.loads[li].to_bits()),
            trial as u64,
        );
        let env = SimEnv::build(&cfg, fseed);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, fseed, &opts);
        Fixture {
            seed: fseed,
            env,
            opts,
            trace,
        }
    });

    struct Cell {
        on_time: Welford,
        drops: usize,
        reroutes: usize,
        tasks: usize,
    }
    let results = run_cells(cells.len(), sc.threads, |i| {
        let (ei, li, si, ri) = cells[i];
        let rate = rates[ri];
        // Schedule stream root keyed by the (rate, load) *values* through
        // a nested stream_seed — value keys cannot alias across cells and
        // keep a cell's schedule stable whatever else is in the grid.
        let sched_root = stream_seed(
            sc.seed,
            axis_stream(TAG_P4_SCHEDULE, rate.to_bits()),
            sc.loads[li].to_bits(),
        );
        let mut on_time = Welford::new();
        let mut drops = 0usize;
        let mut reroutes = 0usize;
        let mut tasks = 0usize;
        // One engine arena per cell, reused across its trials (cleared,
        // not dropped — bit-identical to fresh, asserted in des::tests).
        let mut arena: DesArena = DesArena::new();
        for (trial, fx) in fixtures[li].iter().enumerate() {
            // The schedule adds the rate key on top of the shared fixture.
            let schedule = if rate > 0.0 {
                FaultSchedule::generate(
                    &fx.env.topo,
                    fx.opts.slots,
                    fx.opts.slot_ms,
                    fx.env.app.catalog.num_core(),
                    &FaultParams::from_rate(rate),
                    stream_seed(sched_root, 0, trial as u64),
                )
            } else {
                FaultSchedule::none()
            };
            let mut strategy = strategy_by_name(&sc.strategies[si]).expect("validated");
            let m = match engines[ei] {
                Engine::Slotted => run_trial_faulted(
                    &fx.env,
                    strategy.as_mut(),
                    fx.seed,
                    &fx.opts,
                    &fx.trace,
                    &schedule,
                ),
                Engine::Des => run_des_trial_faulted_in(
                    &mut arena,
                    &fx.env,
                    strategy.as_mut(),
                    fx.seed,
                    &DesOptions::from_sim(&fx.opts),
                    &fx.trace,
                    &schedule,
                ),
            };
            on_time.push(m.on_time_rate());
            drops += m.fault_drops;
            reroutes += m.reroute_recovered;
            tasks += m.total_tasks;
        }
        Cell {
            on_time,
            drops,
            reroutes,
            tasks,
        }
    });

    // "retained" = mean on-time at rate r over the same (engine, load,
    // strategy)'s rate-0 baseline — "-" when the grid has no rate 0.
    let mut table = Table::new(
        "p4 — robustness grid (failure rate x load, paired traces)",
        &[
            "engine",
            "load",
            "fail_rate",
            "strategy",
            "trials",
            "tasks",
            "on_time_mean",
            "on_time_ci95",
            "retained",
            "fault_drops",
            "reroutes",
        ],
    );
    for (i, c) in results.iter().enumerate() {
        let (ei, li, si, ri) = cells[i];
        let baseline = cells
            .iter()
            .position(|&(e2, l2, s2, r2)| {
                e2 == ei && l2 == li && s2 == si && rates[r2] == 0.0
            })
            .map(|j| results[j].on_time.mean());
        // Undefined ("-") when the grid has no rate-0 anchor OR the
        // anchor itself completed nothing on time — a 0/0 ratio must not
        // masquerade as full retention.
        let retained = match baseline {
            Some(b) if b > 0.0 => format!("{:.4}", c.on_time.mean() / b),
            _ => "-".to_string(),
        };
        table.push_row(vec![
            engines[ei].name().to_string(),
            format!("{:.2}", sc.loads[li]),
            format!("{:.4}", rates[ri]),
            sc.strategies[si].clone(),
            sc.trials.to_string(),
            c.tasks.to_string(),
            f6(c.on_time.mean()),
            f6(c.on_time.ci95_half()),
            retained,
            c.drops.to_string(),
            c.reroutes.to_string(),
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// p5 — scenario-robustness ensemble (the scenario library, both engines)
// ---------------------------------------------------------------------

fn sweep_p5(base: &ExperimentConfig, sc: &SweepConfig) -> Result<Table, String> {
    let engines: Vec<Engine> = sc
        .engines
        .iter()
        .map(|e| Engine::parse(e))
        .collect::<Result<_, _>>()?;
    for s in &sc.strategies {
        strategy_by_name(s)?;
    }
    let specs: Vec<ScenarioSpec> = if sc.scenarios.is_empty() {
        ScenarioSpec::library()
    } else {
        sc.scenarios
            .iter()
            .map(|n| {
                ScenarioSpec::by_name(n).ok_or_else(|| format!("unknown scenario `{n}`"))
            })
            .collect::<Result<_, _>>()?
    };

    let mut cells = Vec::new();
    for sci in 0..specs.len() {
        for ei in 0..engines.len() {
            for si in 0..sc.strategies.len() {
                cells.push((sci, ei, si));
            }
        }
    }
    // One environment per trial, shared by EVERY scenario (and engine
    // and strategy): §P5 compares each scenario's row against the
    // baseline scenario's, so rows must differ only by scenario, never
    // by environment realization. Only the scenario compilation stream
    // is keyed by the scenario index. Builds go through `run_cells`
    // (stateless seeds, so parallel building changes nothing).
    let mut cfg = base.clone();
    cfg.sim.slots = sc.slots;
    let envs = run_cells(sc.trials, sc.threads, |trial| {
        let eseed = stream_seed(sc.seed, TAG_P5_ENV, trial as u64);
        let env = SimEnv::build(&cfg, eseed);
        let opts = SimOptions::from_config(&cfg);
        (eseed, env, opts)
    });
    // Compile streams are keyed by the scenario *name*, so one scenario's
    // rows reproduce exactly under any --scenarios subset.
    let compiled: Vec<Vec<CompiledScenario>> =
        run_grid2(specs.len(), sc.trials, sc.threads, |sci, trial| {
            let (_, env, opts) = &envs[trial];
            let cseed = stream_seed(
                sc.seed,
                name_stream(TAG_P5_SCENARIO, &specs[sci].name),
                trial as u64,
            );
            specs[sci].compile(env, opts, cseed)
        });

    struct Cell {
        on_time: Welford,
        completion: Welford,
        drops: usize,
        tasks: usize,
        moves: usize,
        latency: Histogram,
    }
    let results = run_cells(cells.len(), sc.threads, |i| {
        let (sci, ei, si) = cells[i];
        let mut on_time = Welford::new();
        let mut completion = Welford::new();
        let mut drops = 0usize;
        let mut tasks = 0usize;
        let mut moves = 0usize;
        let mut latency = Histogram::latency_ms();
        // Engine storage reused across the cell's trials (clear, don't
        // drop). Kept in retained-metrics mode: the p95 column below
        // needs the raw latency stream.
        let mut arena: DesArena = DesArena::new();
        for (trial, cs) in compiled[sci].iter().enumerate() {
            let (eseed, env, opts) = &envs[trial];
            let mut strategy = strategy_by_name(&sc.strategies[si]).expect("validated");
            let m = match engines[ei] {
                Engine::Slotted => run_trial_faulted(
                    env,
                    strategy.as_mut(),
                    *eseed,
                    opts,
                    &cs.trace,
                    &cs.faults,
                ),
                Engine::Des => run_des_trial_faulted_in(
                    &mut arena,
                    env,
                    strategy.as_mut(),
                    *eseed,
                    &DesOptions::from_sim(opts),
                    &cs.trace,
                    &cs.faults,
                ),
            };
            on_time.push(m.on_time_rate());
            completion.push(m.completion_rate());
            drops += m.fault_drops;
            tasks += m.total_tasks;
            moves += cs.user_moves;
            for &l in &m.latencies_ms {
                latency.record(l);
            }
        }
        Cell {
            on_time,
            completion,
            drops,
            tasks,
            moves,
            latency,
        }
    });
    let mut table = Table::new(
        "p5 — scenario robustness (non-stationary arrivals, churn, correlated outages)",
        &[
            "scenario",
            "engine",
            "strategy",
            "trials",
            "tasks",
            "on_time_mean",
            "on_time_ci95",
            "completion_mean",
            "fault_drops",
            "user_moves",
            "lat_p95_ms",
        ],
    );
    for (i, c) in results.iter().enumerate() {
        let (sci, ei, si) = cells[i];
        table.push_row(vec![
            specs[sci].name.clone(),
            engines[ei].name().to_string(),
            sc.strategies[si].clone(),
            sc.trials.to_string(),
            c.tasks.to_string(),
            f6(c.on_time.mean()),
            f6(c.on_time.ci95_half()),
            f6(c.completion.mean()),
            c.drops.to_string(),
            c.moves.to_string(),
            // "-" when no task completed in the cell — 0.000 would read
            // as an (impossibly) perfect p95 rather than "no data".
            match c.latency.quantile(0.95) {
                Some(q) => format!("{q:.3}"),
                None => "-".to_string(),
            },
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// p10 — elastic autoscaling vs fixed parallelism (crate::pool, §P10)
// ---------------------------------------------------------------------

fn sweep_p10(base: &ExperimentConfig, sc: &SweepConfig) -> Result<Table, String> {
    let engines: Vec<Engine> = sc
        .engines
        .iter()
        .map(|e| Engine::parse(e))
        .collect::<Result<_, _>>()?;
    // The A/B pair: elastic pools driven by the Autoscale strategy vs the
    // pre-pool fixed-parallelism proposal path on the same replayed
    // trace + fault schedule.
    let modes: [(&str, bool); 2] = [("autoscale", true), ("fixed-y", false)];
    let specs: Vec<ScenarioSpec> = if sc.scenarios.is_empty() {
        ["diurnal", "flash-crowd"]
            .iter()
            .map(|n| ScenarioSpec::by_name(n).expect("library scenario"))
            .collect()
    } else {
        sc.scenarios
            .iter()
            .map(|n| {
                ScenarioSpec::by_name(n).ok_or_else(|| format!("unknown scenario `{n}`"))
            })
            .collect::<Result<_, _>>()?
    };

    // Grid order (also row order): scenario, engine, load, mode.
    let mut cells = Vec::new();
    for sci in 0..specs.len() {
        for ei in 0..engines.len() {
            for li in 0..sc.loads.len() {
                for mi in 0..modes.len() {
                    cells.push((sci, ei, li, mi));
                }
            }
        }
    }
    // Fixture keyed by (load, trial) — engines, scenarios, and modes all
    // replay the same realized environment. Env streams are keyed by the
    // load *value*, so a single row reproduces under any --loads subset.
    struct Fixture {
        seed: u64,
        env: SimEnv,
        opts: SimOptions,
    }
    let fixtures = run_grid2(sc.loads.len(), sc.trials, sc.threads, |li, trial| {
        let mut cfg = base.clone();
        cfg.sim.slots = sc.slots;
        cfg.sim.load_multiplier = sc.loads[li];
        let eseed = stream_seed(
            sc.seed,
            axis_stream(TAG_P10_ENV, sc.loads[li].to_bits()),
            trial as u64,
        );
        let env = SimEnv::build(&cfg, eseed);
        let opts = SimOptions::from_config(&cfg);
        Fixture {
            seed: eseed,
            env,
            opts,
        }
    });
    // Compiled scenarios keyed by (scenario name, load value, trial) —
    // both modes and both engines of a cell replay the identical trace
    // and fault schedule (the §P10 pairing).
    let compiled: Vec<Vec<CompiledScenario>> = run_grid2(
        specs.len() * sc.loads.len(),
        sc.trials,
        sc.threads,
        |flat, trial| {
            let (sci, li) = (flat / sc.loads.len(), flat % sc.loads.len());
            let fx = &fixtures[li][trial];
            let croot = stream_seed(
                sc.seed,
                name_stream(TAG_P10_SCENARIO, &specs[sci].name),
                sc.loads[li].to_bits(),
            );
            specs[sci].compile(&fx.env, &fx.opts, stream_seed(croot, 0, trial as u64))
        },
    );

    struct Cell {
        on_time: Welford,
        light_cost: Welford,
        tasks: usize,
        replica_ss: f64,
        cold_starts: u64,
        scale_events: u64,
        pool_size: Histogram,
    }
    let results = run_cells(cells.len(), sc.threads, |i| {
        let (sci, ei, li, mi) = cells[i];
        let pooled = modes[mi].1;
        let mut on_time = Welford::new();
        let mut light_cost = Welford::new();
        let mut tasks = 0usize;
        let mut replica_ss = 0.0f64;
        let mut cold_starts = 0u64;
        let mut scale_events = 0u64;
        let mut pool_size = Histogram::linear(0.0, 512.0, 128);
        // Engine storage reused across the cell's trials (clear, don't
        // drop — bit-identical to fresh, asserted in tests/pool.rs).
        let mut arena: DesArena = DesArena::new();
        for (trial, cs) in compiled[sci * sc.loads.len() + li].iter().enumerate() {
            let fx = &fixtures[li][trial];
            let mut opts = fx.opts.clone();
            let mut strategy: Box<dyn Strategy> = if pooled {
                opts.pool = Some(crate::pool::PoolConfig::from_config(base));
                Box::new(crate::pool::Autoscale::new())
            } else {
                Box::new(Proposal::new())
            };
            let m = match engines[ei] {
                Engine::Slotted => run_trial_faulted(
                    &fx.env,
                    strategy.as_mut(),
                    fx.seed,
                    &opts,
                    &cs.trace,
                    &cs.faults,
                ),
                Engine::Des => run_des_trial_faulted_in(
                    &mut arena,
                    &fx.env,
                    strategy.as_mut(),
                    fx.seed,
                    &DesOptions::from_sim(&opts),
                    &cs.trace,
                    &cs.faults,
                ),
            };
            on_time.push(m.on_time_rate());
            light_cost.push(m.light_cost);
            tasks += m.total_tasks;
            replica_ss += m.pool_replica_slot_seconds;
            cold_starts += m.cold_starts;
            scale_events += m.pool_scale_events;
            // Fixed-y trials carry a default-config (empty) histogram;
            // merge() asserts matching bucket layouts, so skip them.
            if pooled {
                pool_size.merge(&m.pool_size);
            }
        }
        Cell {
            on_time,
            light_cost,
            tasks,
            replica_ss,
            cold_starts,
            scale_events,
            pool_size,
        }
    });
    let mut table = Table::new(
        "p10 — elastic autoscaling vs fixed parallelism (paired traces)",
        &[
            "scenario",
            "engine",
            "mode",
            "load",
            "trials",
            "tasks",
            "on_time_mean",
            "on_time_ci95",
            "light_cost_mean",
            "replica_slot_s",
            "cold_starts",
            "scale_events",
            "pool_p95",
        ],
    );
    for (i, c) in results.iter().enumerate() {
        let (sci, ei, li, mi) = cells[i];
        table.push_row(vec![
            specs[sci].name.clone(),
            engines[ei].name().to_string(),
            modes[mi].0.to_string(),
            format!("{:.2}", sc.loads[li]),
            sc.trials.to_string(),
            c.tasks.to_string(),
            f6(c.on_time.mean()),
            f6(c.on_time.ci95_half()),
            f6(c.light_cost.mean()),
            format!("{:.3}", c.replica_ss),
            c.cold_starts.to_string(),
            c.scale_events.to_string(),
            // "-" on the fixed-y rows (no pool, empty histogram) — 0.000
            // would read as a measured pool size rather than "no pool".
            match c.pool_size.quantile(0.95) {
                Some(q) => format!("{q:.3}"),
                None => "-".to_string(),
            },
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_names_parse() {
        assert_eq!(Experiment::parse("p1b").unwrap(), Experiment::P1b);
        assert_eq!(Experiment::parse("P4").unwrap(), Experiment::P4);
        assert_eq!(Experiment::parse("P10").unwrap(), Experiment::P10);
        assert!(Experiment::parse("p3").is_err());
    }

    #[test]
    fn strategy_factory_covers_the_cli_names() {
        for name in ["proposal", "propavg", "lbrr", "ga", "autoscale"] {
            assert!(strategy_by_name(name).is_ok(), "{name}");
        }
        assert!(strategy_by_name("nope").is_err());
    }

    #[test]
    fn default_grids_are_nonempty() {
        for e in [
            Experiment::P1b,
            Experiment::P2,
            Experiment::P4,
            Experiment::P5,
            Experiment::P10,
        ] {
            let sc = SweepConfig::for_experiment(e);
            assert!(sc.trials > 0);
            assert!(!sc.engines.is_empty());
        }
    }

    #[test]
    fn bad_axis_names_error_before_running() {
        let cfg = ExperimentConfig::paper_default();
        let mut sc = SweepConfig::for_experiment(Experiment::P4);
        sc.strategies = vec!["bogus".into()];
        assert!(run_sweep(&cfg, &sc).unwrap_err().contains("bogus"));
        let mut sc = SweepConfig::for_experiment(Experiment::P5);
        sc.scenarios = vec!["no-such".into()];
        assert!(run_sweep(&cfg, &sc).unwrap_err().contains("no-such"));
        let mut sc = SweepConfig::for_experiment(Experiment::P4);
        sc.engines = vec!["warp".into()];
        assert!(run_sweep(&cfg, &sc).unwrap_err().contains("warp"));
    }
}
