//! The parallel cell runner: scoped worker threads over a seeded grid.
//!
//! Determinism contract: the cell function receives only its cell index,
//! and every random stream a cell uses must be derived statelessly from
//! `(sweep_seed, cell coordinates, trial)` via [`crate::rng::stream_seed`].
//! Under that contract `run_cells` returns bit-identical results for any
//! thread count — workers race only over *which* cell they pull next,
//! never over what a cell computes, and results are re-ordered by cell
//! index before returning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Evaluate `f(0..n)` with up to `threads` workers; results in cell
/// order. `threads <= 1` runs inline (the reference serial order). A
/// panic in any cell propagates (the scope joins all workers first).
pub fn run_cells<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.max(1).min(n.max(1));
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                results.lock().unwrap().push((i, r));
            });
        }
    });
    let mut v = results.into_inner().unwrap();
    v.sort_by_key(|&(i, _)| i);
    debug_assert_eq!(v.len(), n);
    v.into_iter().map(|(_, r)| r).collect()
}

/// Evaluate `f(outer, inner)` over the full `outer x inner` grid with up
/// to `threads` workers, returning results grouped by outer index (each
/// group in inner order). Encodes the flatten/re-chunk pairing in one
/// place so callers cannot misalign the two sides. `inner` must be > 0.
pub fn run_grid2<T, F>(outer: usize, inner: usize, threads: usize, f: F) -> Vec<Vec<T>>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    assert!(inner > 0, "run_grid2 needs a nonempty inner axis");
    let flat = run_cells(outer * inner, threads, |i| f(i / inner, i % inner));
    let mut it = flat.into_iter();
    (0..outer)
        .map(|_| it.by_ref().take(inner).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial_in_order() {
        let f = |i: usize| (i as u64).wrapping_mul(0x9E37_79B9) % 1013;
        let serial = run_cells(57, 1, f);
        for threads in [2, 4, 8] {
            let par = run_cells(57, threads, f);
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn empty_grid_is_fine() {
        let v: Vec<u32> = run_cells(0, 4, |_| 1);
        assert!(v.is_empty());
    }

    #[test]
    fn more_threads_than_cells_is_fine() {
        let v = run_cells(3, 64, |i| i * 2);
        assert_eq!(v, vec![0, 2, 4]);
    }

    #[test]
    fn grid2_groups_align_with_coordinates() {
        let g = run_grid2(3, 4, 4, |o, i| (o, i));
        assert_eq!(g.len(), 3);
        for (o, group) in g.iter().enumerate() {
            assert_eq!(group.len(), 4);
            for (i, &cell) in group.iter().enumerate() {
                assert_eq!(cell, (o, i), "misaligned at ({o},{i})");
            }
        }
        // Degenerate outer axis is fine.
        assert!(run_grid2(0, 2, 2, |o, i| (o, i)).is_empty());
    }
}
