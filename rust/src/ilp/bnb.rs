//! Best-first branch-and-bound over the simplex LP relaxation.
//!
//! The search is *incremental*: one root LP model is built once, each node
//! carries only its bound deltas `(var, lo, hi)` plus the parent's optimal
//! basis, and a child re-optimizes with a dual-simplex pass from that
//! basis after the branching bound is tightened — no `build_lp` + phase-1
//! from cold per node. The dense-rebuild behavior is retained behind
//! [`NodeLpMode::DenseRebuild`] as the benchmark baseline and for
//! cross-checking (`bench_ilp`, `tests/properties.rs`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::lp::{LinProg, LpSolution, LpStatus, Relation, RevisedSimplex, WarmBasis};

use super::model::{IlpError, IlpModel, IlpSolution, IlpStatus};

/// How each node's LP relaxation is solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum NodeLpMode {
    /// Rebuild a dense two-phase simplex from scratch at every node, with
    /// branching bounds encoded as constraint rows (the pre-warm-start
    /// baseline; kept for benchmarking and cross-checks).
    DenseRebuild,
    /// One root revised-simplex model; children warm-start from the
    /// parent's basis and re-optimize with a dual-simplex pass.
    #[default]
    WarmRevised,
}

/// Branch-and-bound options.
#[derive(Clone, Debug)]
pub struct BnbOptions {
    /// Hard cap on explored nodes (safety net; paper instances need few).
    pub max_nodes: usize,
    /// Integrality tolerance.
    pub int_tol: f64,
    /// Relative optimality gap at which search stops.
    pub rel_gap: f64,
    /// Warm-start incumbent `(x, objective)`; must be feasible. Enables
    /// aggressive pruning from the first node.
    pub initial_incumbent: Option<(Vec<f64>, f64)>,
    /// Per-node LP engine.
    pub node_lp: NodeLpMode,
}

impl Default for BnbOptions {
    fn default() -> Self {
        BnbOptions {
            max_nodes: 200_000,
            int_tol: 1e-6,
            rel_gap: 1e-9,
            initial_incumbent: None,
            node_lp: NodeLpMode::WarmRevised,
        }
    }
}

/// Search statistics (exposed to `bench_ilp`).
#[derive(Clone, Copy, Debug, Default)]
pub struct BnbStats {
    pub nodes_explored: usize,
    pub lp_solves: usize,
    /// Node LPs re-optimized from a parent basis (WarmRevised only).
    pub warm_solves: usize,
    /// Node LPs solved from scratch (the root, plus warm-start fallbacks).
    pub cold_solves: usize,
    pub incumbent_updates: usize,
    /// Global lower bound on the optimum: min LP bound over open nodes at
    /// termination (equals the incumbent objective on proven optimality).
    pub best_bound: f64,
    /// Total primal/dual simplex iterations inside the revised engine.
    pub simplex_primal_iters: usize,
    pub simplex_dual_iters: usize,
}

#[derive(Clone, Debug)]
struct Node {
    /// (var, lower, upper) additional bounds along this branch.
    bounds: Vec<(usize, f64, f64)>,
    /// Parent's optimal basis (warm mode; `None` at the root).
    basis: Option<WarmBasis>,
    /// Parent LP bound (priority).
    bound: f64,
    depth: usize,
}

/// Max-heap on -bound => best-first (lowest LP bound first).
impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: smaller bound = higher priority.
        other
            .bound
            .partial_cmp(&self.bound)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.depth.cmp(&self.depth))
    }
}

/// Root LP relaxation with native variable bounds (warm path).
fn build_root_lp(model: &IlpModel) -> LinProg {
    let n = model.num_vars();
    let mut lp = LinProg::minimize(n);
    lp.set_objective(&model.objective);
    for c in &model.constraints {
        let terms: Vec<(usize, f64)> = c.expr.terms.iter().map(|&(v, co)| (v.0, co)).collect();
        lp.add_constraint(&terms, c.rel, c.rhs);
    }
    for (v, k) in model.kinds.iter().enumerate() {
        if let Some(ub) = k.upper_bound() {
            lp.set_upper_bound(v, ub);
        }
    }
    lp
}

/// Per-node LP with branch bounds encoded as rows (dense baseline).
fn build_lp(model: &IlpModel, extra: &[(usize, f64, f64)]) -> LinProg {
    let n = model.num_vars();
    let mut lp = LinProg::minimize(n);
    lp.set_objective(&model.objective);
    for c in &model.constraints {
        let terms: Vec<(usize, f64)> = c.expr.terms.iter().map(|&(v, co)| (v.0, co)).collect();
        lp.add_constraint(&terms, c.rel, c.rhs);
    }
    // Variable domain upper bounds.
    let mut lo = vec![0.0f64; n];
    let mut hi: Vec<f64> = model
        .kinds
        .iter()
        .map(|k| k.upper_bound().unwrap_or(f64::INFINITY))
        .collect();
    for &(v, l, u) in extra {
        lo[v] = lo[v].max(l);
        hi[v] = hi[v].min(u);
    }
    for v in 0..n {
        if lo[v] > 0.0 {
            lp.add_constraint(&[(v, 1.0)], Relation::Ge, lo[v]);
        }
        if hi[v].is_finite() {
            lp.set_upper_bound(v, hi[v]);
        }
    }
    lp
}

/// Solve one node's relaxation on the shared revised engine: reset to the
/// root bounds, apply this node's deltas, warm-start from the parent basis
/// when available (falling back to a cold solve on numerical failure).
fn solve_node_warm(
    engine: &mut RevisedSimplex,
    node: &Node,
    stats: &mut BnbStats,
) -> Result<LpSolution, IlpError> {
    engine.reset_bounds();
    for &(v, l, u) in &node.bounds {
        engine.tighten_var_bounds(v, l, u);
    }
    if let Some(wb) = &node.basis {
        match engine.solve_warm(wb) {
            Ok(sol) => {
                stats.warm_solves += 1;
                return Ok(sol);
            }
            Err(_) => {
                // Singular or cycling warm basis: re-solve from scratch.
                stats.cold_solves += 1;
                return Ok(engine.solve_cold()?);
            }
        }
    }
    stats.cold_solves += 1;
    Ok(engine.solve_cold()?)
}

/// Solve `model` to optimality (or best feasible within node budget).
pub fn solve(model: &IlpModel, opts: &BnbOptions) -> Result<IlpSolution, IlpError> {
    let n = model.num_vars();
    let mut stats = BnbStats {
        best_bound: f64::NEG_INFINITY,
        ..Default::default()
    };

    if n == 0 {
        return Ok(IlpSolution {
            status: IlpStatus::Optimal,
            x: vec![],
            objective: 0.0,
            stats,
        });
    }

    let mut engine = match opts.node_lp {
        NodeLpMode::WarmRevised => Some(RevisedSimplex::new(&build_root_lp(model))?),
        NodeLpMode::DenseRebuild => None,
    };

    let mut heap = BinaryHeap::new();
    heap.push(Node {
        bounds: Vec::new(),
        basis: None,
        bound: f64::NEG_INFINITY,
        depth: 0,
    });

    let mut incumbent: Option<(Vec<f64>, f64)> = opts.initial_incumbent.clone();
    let mut truncated = false;

    while let Some(node) = heap.pop() {
        if stats.nodes_explored >= opts.max_nodes {
            // Best-first: the node just popped has the minimum bound among
            // all open nodes, i.e. the global lower bound at truncation.
            truncated = true;
            stats.best_bound = stats.best_bound.max(node.bound);
            break;
        }
        stats.nodes_explored += 1;
        if node.bound > stats.best_bound {
            stats.best_bound = node.bound;
        }

        // Bound pruning against the incumbent.
        if let Some((_, inc_obj)) = &incumbent {
            if node.bound > *inc_obj - opts.rel_gap * (1.0 + inc_obj.abs()) {
                continue;
            }
        }

        stats.lp_solves += 1;
        let sol = match &mut engine {
            Some(eng) => solve_node_warm(eng, &node, &mut stats)?,
            None => build_lp(model, &node.bounds).solve_dense()?,
        };
        match sol.status {
            LpStatus::Infeasible => continue,
            LpStatus::Unbounded => {
                // Root unbounded LP with integral vars: report unbounded.
                if node.depth == 0 && incumbent.is_none() {
                    return Ok(IlpSolution {
                        status: IlpStatus::Unbounded,
                        x: vec![0.0; n],
                        objective: f64::NEG_INFINITY,
                        stats,
                    });
                }
                continue;
            }
            LpStatus::Optimal => {}
        }
        let bound = sol.objective;
        if let Some((_, inc_obj)) = &incumbent {
            if bound > *inc_obj - opts.rel_gap * (1.0 + inc_obj.abs()) {
                continue;
            }
        }

        // Most-fractional branching variable.
        let mut branch: Option<(usize, f64)> = None;
        let mut best_frac = opts.int_tol;
        for (i, k) in model.kinds.iter().enumerate() {
            if !k.is_integral() {
                continue;
            }
            let v = sol.x[i];
            let frac = (v - v.round()).abs();
            let dist_half = (v - v.floor() - 0.5).abs();
            if frac > opts.int_tol {
                let score = 0.5 - dist_half; // closer to .5 = more fractional
                if branch.is_none() || score > best_frac {
                    best_frac = score.max(opts.int_tol);
                    branch = Some((i, v));
                }
            }
        }

        match branch {
            None => {
                // Integral solution: candidate incumbent.
                let mut x = sol.x.clone();
                for (i, k) in model.kinds.iter().enumerate() {
                    if k.is_integral() {
                        x[i] = x[i].round();
                    }
                }
                let obj = model.objective_at(&x);
                let better = incumbent
                    .as_ref()
                    .map(|(_, io)| obj < *io - 1e-12)
                    .unwrap_or(true);
                if better {
                    incumbent = Some((x, obj));
                    stats.incumbent_updates += 1;
                }
            }
            Some((var, val)) => {
                let floor = val.floor();
                let mut lo_bounds = node.bounds.clone();
                lo_bounds.push((var, 0.0, floor));
                let mut hi_bounds = node.bounds;
                hi_bounds.push((var, floor + 1.0, f64::INFINITY));
                heap.push(Node {
                    bounds: lo_bounds,
                    basis: sol.basis.clone(),
                    bound,
                    depth: node.depth + 1,
                });
                heap.push(Node {
                    bounds: hi_bounds,
                    basis: sol.basis,
                    bound,
                    depth: node.depth + 1,
                });
            }
        }
    }

    if let Some(eng) = &engine {
        let es = eng.stats();
        stats.simplex_primal_iters = es.primal_iters;
        stats.simplex_dual_iters = es.dual_iters;
    }
    if truncated {
        // Open nodes whose bound exceeds the incumbent are worthless (the
        // optimum is the incumbent itself), so the global bound never
        // exceeds the incumbent objective.
        if let Some((_, obj)) = &incumbent {
            stats.best_bound = stats.best_bound.min(*obj);
        }
    } else {
        // Search exhausted: the bound closes onto the incumbent (or +inf
        // when the program is infeasible).
        stats.best_bound = match &incumbent {
            Some((_, obj)) => *obj,
            None => f64::INFINITY,
        };
    }

    match incumbent {
        Some((x, obj)) => Ok(IlpSolution {
            status: if truncated {
                IlpStatus::Feasible
            } else {
                IlpStatus::Optimal
            },
            x,
            objective: obj,
            stats,
        }),
        None => Ok(IlpSolution {
            status: IlpStatus::Infeasible,
            x: vec![0.0; n],
            objective: 0.0,
            stats,
        }),
    }
}
