//! Mixed-integer linear programming substrate: modeling API plus a
//! branch-and-bound solver over the in-tree simplex LP relaxation.
//!
//! Drives the paper's static core-placement program (14) with the
//! sparsity/diversity constraints C4–C6 (big-M indicator coupling and a
//! minimum-support cardinality bound). Instance sizes are modest, so
//! best-first branch-and-bound with LP bounding solves them exactly.
//!
//! Node relaxations are solved incrementally by default
//! ([`NodeLpMode::WarmRevised`]): one root revised-simplex model, per-node
//! bound deltas, and a dual-simplex warm start from the parent's basis.
//! The per-node dense rebuild is kept as [`NodeLpMode::DenseRebuild`] for
//! benchmarking and cross-checks.

mod bnb;
mod model;

pub use bnb::{BnbOptions, BnbStats, NodeLpMode};
pub use model::{IlpError, IlpModel, IlpSolution, IlpStatus, LinExpr, VarId, VarKind};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::Relation;

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c, 3a + 4b + 2c <= 6, binaries -> a=0,b=1,c=1 (20)
        let mut m = IlpModel::new();
        let a = m.add_var(VarKind::Binary, -10.0);
        let b = m.add_var(VarKind::Binary, -13.0);
        let c = m.add_var(VarKind::Binary, -7.0);
        m.add_constraint(
            LinExpr::from_terms(&[(a, 3.0), (b, 4.0), (c, 2.0)]),
            Relation::Le,
            6.0,
        );
        let sol = m.solve(&BnbOptions::default()).unwrap();
        assert_eq!(sol.status, IlpStatus::Optimal);
        assert!((sol.objective + 20.0).abs() < 1e-6);
        assert_eq!(sol.int_value(a), 0);
        assert_eq!(sol.int_value(b), 1);
        assert_eq!(sol.int_value(c), 1);
    }

    #[test]
    fn general_integer_variables() {
        // min 2x + 3y s.t. x + y >= 7.5, x,y ints >= 0 -> x=8,y=0 obj 16
        let mut m = IlpModel::new();
        let x = m.add_var(VarKind::Integer { ub: Some(100) }, 2.0);
        let y = m.add_var(VarKind::Integer { ub: Some(100) }, 3.0);
        m.add_constraint(LinExpr::from_terms(&[(x, 1.0), (y, 1.0)]), Relation::Ge, 7.5);
        let sol = m.solve(&BnbOptions::default()).unwrap();
        assert_eq!(sol.status, IlpStatus::Optimal);
        assert!((sol.objective - 16.0).abs() < 1e-6, "obj={}", sol.objective);
        assert_eq!(sol.int_value(x), 8);
        assert_eq!(sol.int_value(y), 0);
    }

    #[test]
    fn infeasible_integer_program() {
        // x binary, x >= 0.4, x <= 0.6 -> LP feasible, IP infeasible
        let mut m = IlpModel::new();
        let x = m.add_var(VarKind::Binary, 1.0);
        m.add_constraint(LinExpr::from_terms(&[(x, 1.0)]), Relation::Ge, 0.4);
        m.add_constraint(LinExpr::from_terms(&[(x, 1.0)]), Relation::Le, 0.6);
        let sol = m.solve(&BnbOptions::default()).unwrap();
        assert_eq!(sol.status, IlpStatus::Infeasible);
    }

    #[test]
    fn big_m_indicator_pattern() {
        // The C4/C5 pattern: x <= M*ind, x >= eps*ind, plus sum ind >= kappa.
        // Two sites; cost favors site 0; kappa=2 forces both open.
        let mut m = IlpModel::new();
        let x0 = m.add_var(VarKind::Integer { ub: Some(10) }, 1.0);
        let x1 = m.add_var(VarKind::Integer { ub: Some(10) }, 2.0);
        let i0 = m.add_var(VarKind::Binary, 0.0);
        let i1 = m.add_var(VarKind::Binary, 0.0);
        let big_m = 10.0;
        for (x, i) in [(x0, i0), (x1, i1)] {
            m.add_constraint(
                LinExpr::from_terms(&[(x, 1.0), (i, -big_m)]),
                Relation::Le,
                0.0,
            );
            m.add_constraint(
                LinExpr::from_terms(&[(x, 1.0), (i, -1.0)]),
                Relation::Ge,
                0.0,
            );
        }
        // demand: x0 + x1 >= 4
        m.add_constraint(LinExpr::from_terms(&[(x0, 1.0), (x1, 1.0)]), Relation::Ge, 4.0);
        // diversity: i0 + i1 >= 2
        m.add_constraint(LinExpr::from_terms(&[(i0, 1.0), (i1, 1.0)]), Relation::Ge, 2.0);
        let sol = m.solve(&BnbOptions::default()).unwrap();
        assert_eq!(sol.status, IlpStatus::Optimal);
        assert!(sol.int_value(i0) == 1 && sol.int_value(i1) == 1);
        assert!(sol.int_value(x0) >= 1 && sol.int_value(x1) >= 1);
        assert_eq!(sol.int_value(x0) + sol.int_value(x1), 4);
        // optimal splits 3 on cheap site, 1 on the forced-open site
        assert!((sol.objective - (3.0 + 2.0)).abs() < 1e-6);
    }

    #[test]
    fn continuous_and_integer_mixed() {
        // min x + y, x continuous >= 2.5 - y, y integer -> y=3,x=0 obj 3 or
        // y=2,x=0.5 obj 2.5 -> optimum 2.5
        let mut m = IlpModel::new();
        let x = m.add_var(VarKind::Continuous { ub: None }, 1.0);
        let y = m.add_var(VarKind::Integer { ub: Some(10) }, 1.0);
        m.add_constraint(LinExpr::from_terms(&[(x, 1.0), (y, 1.0)]), Relation::Ge, 2.5);
        let sol = m.solve(&BnbOptions::default()).unwrap();
        assert_eq!(sol.status, IlpStatus::Optimal);
        // Multiple optima exist (e.g. y=0,x=2.5 or y=2,x=0.5); check value
        // and feasibility rather than a particular vertex.
        assert!((sol.objective - 2.5).abs() < 1e-6);
        assert!(sol.x[x.0] + sol.x[y.0] >= 2.5 - 1e-6);
        assert!((sol.x[y.0] - sol.x[y.0].round()).abs() < 1e-6);
    }

    #[test]
    fn warm_and_dense_node_lp_modes_agree() {
        // A branching-heavy instance: near-tie objective over binaries
        // plus a coupling row, solved exactly under both node-LP engines.
        let mut m = IlpModel::new();
        let vars: Vec<_> = (0..10)
            .map(|i| m.add_var(VarKind::Binary, -(1.0 + 0.013 * i as f64)))
            .collect();
        let terms: Vec<_> = vars.iter().map(|&v| (v, 1.0)).collect();
        m.add_constraint(LinExpr::from_terms(&terms), Relation::Le, 4.0);
        let w: Vec<_> = vars
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, 1.0 + (i % 3) as f64))
            .collect();
        m.add_constraint(LinExpr::from_terms(&w), Relation::Le, 7.0);
        let warm = m
            .solve(&BnbOptions {
                node_lp: NodeLpMode::WarmRevised,
                ..Default::default()
            })
            .unwrap();
        let dense = m
            .solve(&BnbOptions {
                node_lp: NodeLpMode::DenseRebuild,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(warm.status, IlpStatus::Optimal);
        assert_eq!(dense.status, IlpStatus::Optimal);
        assert!(
            (warm.objective - dense.objective).abs() < 1e-6,
            "warm={} dense={}",
            warm.objective,
            dense.objective
        );
        // Proven optimality closes the gap: best bound == objective.
        assert!((warm.stats.best_bound - warm.objective).abs() < 1e-9);
        assert!((dense.stats.best_bound - dense.objective).abs() < 1e-9);
        // The warm path actually warm-started (only the root is cold,
        // modulo rare numerical fallbacks).
        if warm.stats.nodes_explored > 1 {
            assert!(warm.stats.warm_solves > 0, "{:?}", warm.stats);
        }
    }

    #[test]
    fn best_bound_tracks_global_bound_not_node_bound() {
        // Truncated search must report a lower bound <= the incumbent (the
        // old code overwrote it with the current node's LP objective).
        let mut m = IlpModel::new();
        let vars: Vec<_> = (0..14)
            .map(|i| m.add_var(VarKind::Binary, -(1.0 + 0.01 * i as f64)))
            .collect();
        let terms: Vec<_> = vars.iter().map(|&v| (v, 1.0)).collect();
        m.add_constraint(LinExpr::from_terms(&terms), Relation::Le, 7.0);
        let sol = m
            .solve(&BnbOptions {
                max_nodes: 4,
                ..Default::default()
            })
            .unwrap();
        if sol.status == IlpStatus::Feasible {
            assert!(
                sol.stats.best_bound <= sol.objective + 1e-9,
                "bound {} must not exceed incumbent {}",
                sol.stats.best_bound,
                sol.objective
            );
        } else {
            assert_eq!(sol.status, IlpStatus::Optimal);
            assert!((sol.stats.best_bound - sol.objective).abs() < 1e-9);
        }
    }

    #[test]
    fn node_limit_returns_feasible_if_found() {
        let mut m = IlpModel::new();
        // 12 binaries, near-tie objective to force branching.
        let vars: Vec<_> = (0..12)
            .map(|i| m.add_var(VarKind::Binary, -(1.0 + 0.01 * i as f64)))
            .collect();
        let terms: Vec<_> = vars.iter().map(|&v| (v, 1.0)).collect();
        m.add_constraint(LinExpr::from_terms(&terms), Relation::Le, 6.0);
        let opts = BnbOptions {
            max_nodes: 5,
            ..Default::default()
        };
        let sol = m.solve(&opts).unwrap();
        // Either optimal quickly or feasible-with-limit; must not error.
        assert!(matches!(sol.status, IlpStatus::Optimal | IlpStatus::Feasible));
    }
}
