//! MILP modeling API: variables, linear expressions, constraints.

use crate::lp::Relation;

use super::bnb::{self, BnbOptions};

/// Variable handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct VarId(pub usize);

/// Variable domain.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum VarKind {
    /// Continuous `0 <= x <= ub`.
    Continuous { ub: Option<f64> },
    /// Integer `0 <= x <= ub`.
    Integer { ub: Option<u64> },
    /// Binary `x in {0, 1}`.
    Binary,
}

impl VarKind {
    pub fn is_integral(&self) -> bool {
        matches!(self, VarKind::Integer { .. } | VarKind::Binary)
    }

    pub fn upper_bound(&self) -> Option<f64> {
        match self {
            VarKind::Continuous { ub } => *ub,
            VarKind::Integer { ub } => ub.map(|u| u as f64),
            VarKind::Binary => Some(1.0),
        }
    }
}

/// A linear expression `sum coeff_i * var_i`.
#[derive(Clone, Debug, Default)]
pub struct LinExpr {
    pub terms: Vec<(VarId, f64)>,
}

impl LinExpr {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_terms(terms: &[(VarId, f64)]) -> Self {
        LinExpr {
            terms: terms.to_vec(),
        }
    }

    /// Append `coeff * var`.
    pub fn add(&mut self, var: VarId, coeff: f64) -> &mut Self {
        self.terms.push((var, coeff));
        self
    }

    /// Evaluate at a point.
    pub fn eval(&self, x: &[f64]) -> f64 {
        self.terms.iter().map(|&(v, c)| c * x[v.0]).sum()
    }
}

/// Constraint row.
#[derive(Clone, Debug)]
pub struct IlpConstraint {
    pub expr: LinExpr,
    pub rel: Relation,
    pub rhs: f64,
}

/// Solver status for MILP.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IlpStatus {
    /// Proven optimal.
    Optimal,
    /// Feasible incumbent found but search truncated (node limit).
    Feasible,
    Infeasible,
    Unbounded,
}

/// MILP errors.
#[derive(Debug, Clone, PartialEq)]
pub enum IlpError {
    Lp(crate::lp::LpError),
    /// Model has no variables but constraints reference some.
    Malformed(String),
}

impl std::fmt::Display for IlpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IlpError::Lp(e) => write!(f, "LP relaxation error: {e}"),
            IlpError::Malformed(s) => write!(f, "malformed model: {s}"),
        }
    }
}

impl std::error::Error for IlpError {}

impl From<crate::lp::LpError> for IlpError {
    fn from(e: crate::lp::LpError) -> Self {
        IlpError::Lp(e)
    }
}

/// MILP solution.
#[derive(Clone, Debug)]
pub struct IlpSolution {
    pub status: IlpStatus,
    pub x: Vec<f64>,
    pub objective: f64,
    /// Branch-and-bound statistics for the benches.
    pub stats: super::BnbStats,
}

impl IlpSolution {
    /// Rounded integer value of a variable (valid for integral kinds).
    pub fn int_value(&self, v: VarId) -> u64 {
        self.x[v.0].round().max(0.0) as u64
    }
}

/// A minimization MILP under construction.
#[derive(Clone, Debug, Default)]
pub struct IlpModel {
    pub(crate) kinds: Vec<VarKind>,
    pub(crate) objective: Vec<f64>,
    pub(crate) constraints: Vec<IlpConstraint>,
}

impl IlpModel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a variable with the given domain and objective coefficient.
    pub fn add_var(&mut self, kind: VarKind, obj_coeff: f64) -> VarId {
        self.kinds.push(kind);
        self.objective.push(obj_coeff);
        VarId(self.kinds.len() - 1)
    }

    pub fn num_vars(&self) -> usize {
        self.kinds.len()
    }

    pub fn num_constraints(&self) -> usize {
        self.constraints.len()
    }

    /// Add `expr {rel} rhs`.
    pub fn add_constraint(&mut self, expr: LinExpr, rel: Relation, rhs: f64) {
        self.constraints.push(IlpConstraint { expr, rel, rhs });
    }

    /// Solve by branch-and-bound.
    pub fn solve(&self, opts: &BnbOptions) -> Result<IlpSolution, IlpError> {
        bnb::solve(self, opts)
    }

    /// Check a point against all constraints and integrality (used by the
    /// property tests and the greedy fallback validator).
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.kinds.len() {
            return false;
        }
        for (i, k) in self.kinds.iter().enumerate() {
            if x[i] < -tol {
                return false;
            }
            if let Some(ub) = k.upper_bound() {
                if x[i] > ub + tol {
                    return false;
                }
            }
            if k.is_integral() && (x[i] - x[i].round()).abs() > tol {
                return false;
            }
        }
        for c in &self.constraints {
            let lhs = c.expr.eval(x);
            let ok = match c.rel {
                Relation::Le => lhs <= c.rhs + tol,
                Relation::Eq => (lhs - c.rhs).abs() <= tol,
                Relation::Ge => lhs >= c.rhs - tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }

    /// Objective at a point.
    pub fn objective_at(&self, x: &[f64]) -> f64 {
        self.objective.iter().zip(x).map(|(c, v)| c * v).sum()
    }
}
