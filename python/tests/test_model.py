"""Layer-2 graph tests: g-table semantics and the transformer block."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _samples(m, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.gamma(1.5, 10.0, size=(m, s)), jnp.float32)


def _thetas(t=16):
    return jnp.asarray(np.geomspace(1e-3, 10.0, t), jnp.float32)


class TestEffcapTable:
    def test_shapes(self):
        g, gm = model.effcap_table(
            _samples(3, 512), _thetas(), jnp.ones((3,), jnp.float32),
            max_y=8, alpha=1.0, epsilon=0.2,
        )
        assert g.shape == (3, 8) and gm.shape == (3, 8)

    def test_bound_dominates_mean(self):
        g, gm = model.effcap_table(
            _samples(4, 2048), _thetas(), jnp.ones((4,), jnp.float32),
            max_y=16, alpha=1.0, epsilon=0.2,
        )
        assert (np.asarray(g) >= np.asarray(gm) - 1e-6).all()

    def test_monotone_in_y(self):
        g, _ = model.effcap_table(
            _samples(4, 2048), _thetas(), jnp.ones((4,), jnp.float32),
            max_y=16, alpha=1.0, epsilon=0.2,
        )
        assert (np.diff(np.asarray(g), axis=1) >= -1e-6).all()

    def test_epsilon_ordering(self):
        s = _samples(2, 2048)
        w = jnp.ones((2,), jnp.float32)
        strict, _ = model.effcap_table(s, _thetas(), w, max_y=8, alpha=1.0, epsilon=0.05)
        loose, _ = model.effcap_table(s, _thetas(), w, max_y=8, alpha=1.0, epsilon=0.5)
        assert (np.asarray(strict) >= np.asarray(loose) - 1e-6).all()

    def test_clamped_at_20x_mean(self):
        g, gm = model.effcap_table(
            _samples(2, 256, seed=3), _thetas(4), jnp.ones((2,), jnp.float32),
            max_y=16, alpha=2.0, epsilon=0.01,
        )
        assert np.isfinite(np.asarray(g)).all()
        assert (np.asarray(g) <= 20.0 * np.asarray(gm) + 1e-5).all()

    def test_deterministic_rates_give_mean_delay(self):
        s = jnp.full((1, 256), 4.0, jnp.float32)
        thetas = jnp.asarray(np.geomspace(1e-3, 1e4, 64), jnp.float32)
        g, gm = model.effcap_table(
            s, thetas, jnp.asarray([2.0], jnp.float32),
            max_y=4, alpha=1.0, epsilon=0.2,
        )
        np.testing.assert_allclose(gm[0, 0], 0.5, rtol=1e-6)
        assert 0.5 <= float(g[0, 0]) < 0.502


class TestMsBlock:
    def test_shape_preserved(self):
        p = model.ms_block_params(64, 128)
        x = jnp.ones((2, 8, 64), jnp.float32)
        y = model.ms_block(p, x)
        assert y.shape == x.shape

    def test_deterministic(self):
        p = model.ms_block_params(64, 128)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
        y1 = model.ms_block(p, x)
        y2 = model.ms_block(p, x)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_not_identity(self):
        p = model.ms_block_params(64, 128)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 64))
        y = model.ms_block(p, x)
        assert float(jnp.abs(y - x).max()) > 1e-3

    @pytest.mark.parametrize("b,l", [(1, 1), (4, 16)])
    def test_batch_shapes(self, b, l):
        p = model.ms_block_params(32, 64)
        x = jnp.zeros((b, l, 32), jnp.float32)
        assert model.ms_block(p, x).shape == (b, l, 32)
