"""AOT pipeline smoke tests: lowering produces loadable HLO text whose
numerics match direct jnp execution (the Rust runtime re-checks this
end-to-end in rust/tests/)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def _run_hlo_text(text: str, args):
    """Compile HLO text back through XLA and execute (round-trip check)."""
    from jax._src.lib import xla_client as xc

    client = xc.make_cpu_client()
    # Parse HLO text via the same entry point the rust `xla` crate uses.
    comp = xc._xla.hlo_module_from_text(text)
    exe = client.compile(xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto()))
    out = exe.execute_sharded(
        [[client.buffer_from_pyval(np.asarray(a))] for a in args]
    )
    return [np.asarray(x[0]) for x in out.disassemble_into_single_device_arrays()]


def test_effcap_lowering_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_effcap())
    assert "HloModule" in text
    assert len(text) > 1000


def test_qos_lowering_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_qos())
    assert "HloModule" in text


def test_msblock_lowering_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_msblock())
    assert "HloModule" in text
    assert "dot" in text  # matmuls survived lowering


def test_manifest_lists_all_artifacts():
    for name in ("effcap.hlo.txt", "qos.hlo.txt", "msblock.hlo.txt"):
        assert name in aot.MANIFEST


@pytest.mark.parametrize("seed", [0, 1])
def test_effcap_hlo_roundtrip_matches_jit(seed):
    rng = np.random.default_rng(seed)
    samples = rng.gamma(1.5, 10.0, size=(aot.EFFCAP_M, aot.EFFCAP_S)).astype(np.float32)
    thetas = np.geomspace(1e-3, 10.0, aot.EFFCAP_T).astype(np.float32)
    workload = rng.uniform(0.5, 2.0, aot.EFFCAP_M).astype(np.float32)
    want_g, want_gm = model.effcap_table(
        jnp.asarray(samples), jnp.asarray(thetas), jnp.asarray(workload),
        max_y=aot.EFFCAP_Y, alpha=aot.EFFCAP_ALPHA, epsilon=aot.EFFCAP_EPSILON,
    )
    text = aot.to_hlo_text(aot.lower_effcap())
    try:
        outs = _run_hlo_text(text, [samples, thetas, workload])
    except Exception as e:  # pragma: no cover - environment-specific API
        pytest.skip(f"python-side HLO re-execution unavailable: {e}")
    np.testing.assert_allclose(outs[0], np.asarray(want_g), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[1], np.asarray(want_gm), rtol=1e-5, atol=1e-6)
