"""Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes and distribution parameters; every case asserts
allclose between the tiled kernel and the reference, plus analytic checks
against the Gamma closed form shared with the Rust implementation.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.effcap import effcap_lme
from compile.kernels.qos import qos_apportion
from compile.kernels.ref import (
    effcap_lme_ref,
    gamma_effective_capacity,
    qos_apportion_ref,
)

jax.config.update("jax_platform_name", "cpu")

HSETTINGS = dict(deadline=None, max_examples=20, derandomize=True)


# ------------------------------------------------------------------ effcap --


def _samples(m, s, seed=0, shape=1.5, scale=10.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.gamma(shape, scale, size=(m, s)), jnp.float32)


def _thetas(t, lo=1e-3, hi=10.0):
    return jnp.asarray(np.geomspace(lo, hi, t), jnp.float32)


@pytest.mark.parametrize("m,s,t,y", [(1, 64, 4, 4), (3, 256, 8, 16), (16, 1024, 32, 16)])
def test_effcap_matches_ref(m, s, t, y):
    samples = _samples(m, s)
    thetas = _thetas(t)
    got = effcap_lme(samples, thetas, max_y=y, alpha=1.0)
    want = effcap_lme_ref(samples, thetas, max_y=y, alpha=1.0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@hypothesis.settings(**HSETTINGS)
@hypothesis.given(
    m=st.integers(1, 8),
    s=st.sampled_from([32, 128, 512]),
    t=st.integers(2, 16),
    y=st.integers(1, 16),
    alpha=st.sampled_from([0.5, 1.0, 1.5]),
    seed=st.integers(0, 2**16),
)
def test_effcap_matches_ref_hypothesis(m, s, t, y, alpha, seed):
    samples = _samples(m, s, seed=seed)
    thetas = _thetas(t)
    got = effcap_lme(samples, thetas, max_y=y, alpha=alpha)
    want = effcap_lme_ref(samples, thetas, max_y=y, alpha=alpha)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_effcap_against_gamma_closed_form():
    # E^c(theta) = -LME/theta must match k*ln(1+theta*s)/theta at y=1.
    shape_k, scale_s = 1.5, 10.0
    samples = _samples(1, 200_000, seed=7, shape=shape_k, scale=scale_s)
    thetas = _thetas(8, 0.01, 3.0)
    lme = effcap_lme(samples, thetas, max_y=1, alpha=1.0)  # [1, T, 1]
    ec = -lme[0, :, 0] / thetas
    want = gamma_effective_capacity(shape_k, scale_s, thetas)
    np.testing.assert_allclose(ec, want, rtol=0.03)


def test_effcap_monotone_in_y():
    # Higher contention (larger y) can only shrink E^c => raise LME.
    samples = _samples(2, 2048, seed=3)
    thetas = _thetas(6)
    lme = np.asarray(effcap_lme(samples, thetas, max_y=8, alpha=1.0))
    diffs = np.diff(lme, axis=2)
    assert (diffs >= -1e-6).all(), "LME must be nondecreasing in y"


def test_effcap_deterministic_rates():
    # f identically c: LME = -theta*c/y^alpha exactly.
    c = 5.0
    samples = jnp.full((1, 128), c, jnp.float32)
    thetas = _thetas(5)
    lme = effcap_lme(samples, thetas, max_y=4, alpha=1.0)
    ys = np.arange(1, 5, dtype=np.float32)
    want = -np.asarray(thetas)[None, :, None] * c / ys[None, None, :]
    np.testing.assert_allclose(lme, want, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- qos --


def _qos_inputs(r, v, c, seed=0):
    rng = np.random.default_rng(seed)
    dpr = jnp.asarray(rng.uniform(0.5, 30.0, (r, v)), jnp.float32)
    z = jnp.asarray(rng.uniform(0.0, 1.5, (r,)), jnp.float32)
    dd = jnp.asarray(rng.uniform(50.0, 100.0, (r,)), jnp.float32)
    dcu = jnp.asarray(rng.uniform(0.1, 2.0, (r,)), jnp.float32)
    dsu = jnp.asarray(rng.uniform(0.05, 5.0, (r,)), jnp.float32)
    onehot = np.zeros((r, c), np.float32)
    onehot[np.arange(r), rng.integers(0, c, r)] = 1.0
    return dpr, z, dd, dcu, dsu, jnp.asarray(onehot)


@pytest.mark.parametrize("r,v,c,tile", [(64, 8, 4, 64), (256, 32, 8, 64), (128, 16, 6, 32)])
def test_qos_matches_ref(r, v, c, tile):
    args = _qos_inputs(r, v, c)
    kw = dict(delta=0.05, lo=0.05, hi=4.0)
    zt, dt = qos_apportion(*args, row_tile=tile, **kw)
    zt_ref, dt_ref = qos_apportion_ref(*args, **kw)
    np.testing.assert_allclose(zt, zt_ref, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(dt, dt_ref, rtol=2e-5, atol=2e-5)


@hypothesis.settings(**HSETTINGS)
@hypothesis.given(
    tiles=st.integers(1, 4),
    v=st.integers(2, 24),
    c=st.integers(1, 8),
    delta=st.sampled_from([0.01, 0.05, 0.5]),
    seed=st.integers(0, 2**16),
)
def test_qos_matches_ref_hypothesis(tiles, v, c, delta, seed):
    r = 32 * tiles
    args = _qos_inputs(r, v, c, seed=seed)
    kw = dict(delta=delta, lo=0.05, hi=4.0)
    zt, dt = qos_apportion(*args, row_tile=32, **kw)
    zt_ref, dt_ref = qos_apportion_ref(*args, **kw)
    np.testing.assert_allclose(zt, zt_ref, rtol=5e-5, atol=5e-6)
    np.testing.assert_allclose(dt, dt_ref, rtol=5e-5, atol=5e-5)


def test_qos_mass_conservation():
    # Summing zt over nodes recovers the per-core total arrival mass.
    r, v, c = 128, 16, 4
    dpr, z, dd, dcu, dsu, group = _qos_inputs(r, v, c, seed=5)
    zt, _ = qos_apportion(
        dpr, z, dd, dcu, dsu, group, delta=0.05, lo=0.05, hi=4.0, row_tile=64
    )
    want = np.asarray(group).T @ np.asarray(z)
    np.testing.assert_allclose(np.asarray(zt).sum(axis=0), want, rtol=2e-5)


def test_qos_padding_rows_are_inert():
    r, v, c = 64, 8, 4
    dpr, z, dd, dcu, dsu, group = _qos_inputs(r, v, c, seed=9)
    kw = dict(delta=0.05, lo=0.05, hi=4.0, row_tile=64)
    zt0, dt0 = qos_apportion(dpr, z, dd, dcu, dsu, group, **kw)
    # Append a tile of padding rows: z=0, group=0.
    pad = 64
    dpr2 = jnp.concatenate([dpr, jnp.ones((pad, v), jnp.float32)])
    z2 = jnp.concatenate([z, jnp.zeros((pad,), jnp.float32)])
    dd2 = jnp.concatenate([dd, jnp.ones((pad,), jnp.float32)])
    dcu2 = jnp.concatenate([dcu, jnp.ones((pad,), jnp.float32)])
    dsu2 = jnp.concatenate([dsu, jnp.ones((pad,), jnp.float32)])
    group2 = jnp.concatenate([group, jnp.zeros((pad, c), jnp.float32)])
    zt1, dt1 = qos_apportion(dpr2, z2, dd2, dcu2, dsu2, group2, **kw)
    np.testing.assert_allclose(zt1, zt0, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(dt1, dt0, rtol=1e-6, atol=1e-7)
