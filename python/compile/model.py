"""Layer-2 JAX graphs, AOT-lowered to the artifacts the Rust runtime runs.

Three build-time computations (see DESIGN.md section 2):

* ``effcap_table`` — sampled service rates -> the paper's deterministic
  map ``g_{m,eps}(y)`` (QoS delay bound per light MS x parallelism) plus
  the mean-value variant used by the PropAvg ablation. Mirrors
  ``rust/src/effcap`` exactly (Chernoff inversion, mean floor, 20x-mean
  clamp, monotonize) so the native and PJRT paths agree to fp tolerance.
* ``qos_scores`` — mean-value latency profiles -> apportioned load
  ``z~[v,c]`` and QoS score ``Q[v,c]`` (eqs. 15-16), mirroring
  ``rust/src/placement/qos_score.rs``.
* ``ms_block`` — a small transformer block standing in for a core-MS
  forward pass; the serving example executes it per request through PJRT
  so the demo exercises real MXU-shaped compute on the hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.effcap import effcap_lme
from .kernels.qos import qos_apportion

# ---------------------------------------------------------------- effcap ----


@functools.partial(jax.jit, static_argnames=("max_y", "alpha", "epsilon"))
def effcap_table(
    samples: jax.Array,
    thetas: jax.Array,
    workload_mb: jax.Array,
    *,
    max_y: int,
    alpha: float,
    epsilon: float,
):
    """Build ``(g, g_mean)`` delay tables, both ``f32[M, Y]``.

    Chernoff inversion of the service-rate lower tail (DESIGN.md section 5):
      ``D(theta) = a / (E^c(theta) + ln(eps)/theta)`` where the effective
      capacity at parallelism y comes from the Pallas LME kernel,
      ``E^c_y(theta) = -LME[m, t, y] / theta``.
    """
    lme = effcap_lme(samples, thetas, max_y=max_y, alpha=alpha)  # [M,T,Y]
    ec = -lme / thetas[None, :, None]  # [M, T, Y]
    ln_eps = jnp.log(jnp.asarray(epsilon, samples.dtype))
    denom = ec + ln_eps / thetas[None, :, None]  # [M, T, Y]
    a = workload_mb[:, None, None]
    d = jnp.where(denom > 0.0, a / denom, jnp.inf)  # [M, T, Y]
    bound = jnp.min(d, axis=1)  # [M, Y]

    # Mean-value floor and PropAvg row.
    mu = jnp.mean(samples, axis=1)  # [M]
    ys = jnp.arange(1, max_y + 1, dtype=samples.dtype)
    mean_delay = workload_mb[:, None] * (ys[None, :] ** alpha) / mu[:, None]
    g = jnp.maximum(bound, mean_delay)
    # Clamp blow-ups (no positive-denominator theta) to 20x mean delay.
    g = jnp.minimum(g, 20.0 * mean_delay)
    # Monotonize along y (contention can only increase the bound).
    g = jax.lax.associative_scan(jnp.maximum, g, axis=1)
    return g, mean_delay


# ------------------------------------------------------------- qos scores ---


@functools.partial(jax.jit, static_argnames=("delta", "lo", "hi"))
def qos_scores(
    dpr: jax.Array,
    z: jax.Array,
    deadlines: jax.Array,
    dcu: jax.Array,
    dsu: jax.Array,
    group: jax.Array,
    *,
    delta: float,
    lo: float,
    hi: float,
):
    """Apportioned load, urgency and QoS score: ``(zt, dt, q)`` f32[V, C]."""
    zt, dt = qos_apportion(
        dpr, z, deadlines, dcu, dsu, group, delta=delta, lo=lo, hi=hi
    )
    return zt, dt, zt * dt


# ---------------------------------------------------------------- msblock ---


def ms_block_params(d_model: int = 256, d_ff: int = 512, seed: int = 0):
    """Deterministic demo weights for the core-MS transformer block."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)
    s = 1.0 / jnp.sqrt(d_model)
    return {
        "wq": jax.random.normal(ks[0], (d_model, d_model), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d_model, d_model), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d_model, d_model), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (d_model, d_model), jnp.float32) * s,
        "w1": jax.random.normal(ks[4], (d_model, d_ff), jnp.float32) * s,
        "w2": jax.random.normal(ks[5], (d_ff, d_model), jnp.float32) * s,
    }


def _layernorm(x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


@jax.jit
def ms_block(params, x):
    """Single-head attention + MLP block: ``f32[B, L, D] -> f32[B, L, D]``."""
    h = _layernorm(x)
    q = h @ params["wq"]
    k = h @ params["wk"]
    v = h @ params["wv"]
    scores = q @ jnp.swapaxes(k, -1, -2) / jnp.sqrt(jnp.float32(q.shape[-1]))
    attn = jax.nn.softmax(scores, axis=-1) @ v
    x = x + attn @ params["wo"]
    h = _layernorm(x)
    x = x + jax.nn.gelu(h @ params["w1"]) @ params["w2"]
    return x
