"""AOT-lower the Layer-2 graphs to HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the vendored
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (shapes fixed at compile time; Rust pads inputs):

* ``effcap.hlo.txt``  — (samples f32[M,S], thetas f32[T], workload f32[M])
                        -> (g f32[M,Y], g_mean f32[M,Y])
* ``qos.hlo.txt``     — (dpr f32[R,V], z f32[R], D f32[R], dcu f32[R],
                        dsu f32[R], group f32[R,C]) -> (zt, dt, q) f32[V,C]
* ``msblock.hlo.txt`` — (x f32[B,L,D]) -> f32[B,L,D] (weights constant-folded)

A ``manifest.txt`` records every artifact's shapes and static parameters so
the Rust side can validate at load time.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Compile-time shape configuration (kept in sync with rust/src/runtime).
EFFCAP_M = 16
EFFCAP_S = 4096
EFFCAP_T = 32
EFFCAP_Y = 16
EFFCAP_ALPHA = 1.0
EFFCAP_EPSILON = 0.2

QOS_R = 512
QOS_V = 32
QOS_C = 8
QOS_DELTA = 0.05
QOS_LO = 0.05
QOS_HI = 4.0

MSBLOCK_B = 4
MSBLOCK_L = 16
MSBLOCK_D = 256


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_effcap():
    fn = functools.partial(
        model.effcap_table,
        max_y=EFFCAP_Y,
        alpha=EFFCAP_ALPHA,
        epsilon=EFFCAP_EPSILON,
    )
    spec = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)  # noqa: E731
    return jax.jit(fn).lower(
        spec(EFFCAP_M, EFFCAP_S), spec(EFFCAP_T), spec(EFFCAP_M)
    )


def lower_qos():
    fn = functools.partial(
        model.qos_scores, delta=QOS_DELTA, lo=QOS_LO, hi=QOS_HI
    )
    spec = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)  # noqa: E731
    return jax.jit(fn).lower(
        spec(QOS_R, QOS_V),
        spec(QOS_R),
        spec(QOS_R),
        spec(QOS_R),
        spec(QOS_R),
        spec(QOS_R, QOS_C),
    )


# Weight argument order for the msblock artifact (and the sidecar
# ``msblock.weights.bin`` raw-f32 file): must match MsBlockAccel.
MSBLOCK_WEIGHT_ORDER = ("wq", "wk", "wv", "wo", "w1", "w2")


def lower_msblock():
    # Weights are *arguments*, not closure constants: ``as_hlo_text``
    # elides large constants as ``{...}`` which the Rust-side HLO parser
    # would silently fill with zeros. The sidecar weights file carries the
    # actual values (see write_msblock_weights).
    def fn(wq, wk, wv, wo, w1, w2, x):
        params = dict(wq=wq, wk=wk, wv=wv, wo=wo, w1=w1, w2=w2)
        return (model.ms_block(params, x),)

    p = model.ms_block_params(MSBLOCK_D)
    specs = [jax.ShapeDtypeStruct(p[k].shape, jnp.float32) for k in MSBLOCK_WEIGHT_ORDER]
    specs.append(
        jax.ShapeDtypeStruct((MSBLOCK_B, MSBLOCK_L, MSBLOCK_D), jnp.float32)
    )
    return jax.jit(fn).lower(*specs)


def write_msblock_weights(out_dir: str) -> None:
    """Raw little-endian f32 concatenation in MSBLOCK_WEIGHT_ORDER."""
    import numpy as np

    p = model.ms_block_params(MSBLOCK_D)
    path = os.path.join(out_dir, "msblock.weights.bin")
    with open(path, "wb") as f:
        for k in MSBLOCK_WEIGHT_ORDER:
            f.write(np.asarray(p[k], np.float32).tobytes())
    print(f"wrote weights to {path}")


MANIFEST = f"""# fmedge AOT manifest v1
effcap.hlo.txt inputs samples:f32[{EFFCAP_M},{EFFCAP_S}] thetas:f32[{EFFCAP_T}] workload:f32[{EFFCAP_M}] outputs g:f32[{EFFCAP_M},{EFFCAP_Y}] gmean:f32[{EFFCAP_M},{EFFCAP_Y}] params alpha={EFFCAP_ALPHA} epsilon={EFFCAP_EPSILON}
qos.hlo.txt inputs dpr:f32[{QOS_R},{QOS_V}] z:f32[{QOS_R}] deadlines:f32[{QOS_R}] dcu:f32[{QOS_R}] dsu:f32[{QOS_R}] group:f32[{QOS_R},{QOS_C}] outputs zt:f32[{QOS_V},{QOS_C}] dt:f32[{QOS_V},{QOS_C}] q:f32[{QOS_V},{QOS_C}] params delta={QOS_DELTA} lo={QOS_LO} hi={QOS_HI}
msblock.hlo.txt inputs x:f32[{MSBLOCK_B},{MSBLOCK_L},{MSBLOCK_D}] outputs y:f32[{MSBLOCK_B},{MSBLOCK_L},{MSBLOCK_D}] params d_model={MSBLOCK_D}
"""


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        choices=["effcap", "qos", "msblock"],
        default=None,
        help="build a single artifact",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = {
        "effcap": lower_effcap,
        "qos": lower_qos,
        "msblock": lower_msblock,
    }
    if args.only:
        jobs = {args.only: jobs[args.only]}
    for name, lower in jobs.items():
        text = to_hlo_text(lower())
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")
    if args.only in (None, "msblock"):
        write_msblock_weights(args.out_dir)
    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write(MANIFEST)
    print(f"wrote manifest to {manifest}")


if __name__ == "__main__":
    main()
