"""Layer-1 Pallas kernel: effective-capacity log-mean-exp sweep.

Computes, for every light microservice `m`, QoS exponent `theta_t` and
parallelism level `y in 1..Y`:

    LME[m, t, y-1] = log( mean_s exp( -theta_t * f[m, s] / y**alpha ) )

which the Layer-2 graph turns into the effective capacity
`E^c = -LME / theta` and the Chernoff delay bound `g_{m,eps}(y)`
(eq. 20-21 of the paper; see rust/src/effcap for the mirrored native
implementation and DESIGN.md section 5 for the derivation).

TPU shape rationale: the grid is (M, T); each program instance holds one
(microservice, theta) pair's full sample vector in VMEM and materializes
the [Y, S] scaled matrix (16 x 4096 f32 = 256 KiB, comfortably within a
TPU core's ~16 MiB VMEM), reducing over the sample axis with a stable
max-shifted log-sum-exp. `interpret=True` everywhere: the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness is validated against
`ref.py` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["effcap_lme"]


def _lme_kernel(samples_ref, thetas_ref, out_ref, *, max_y: int, alpha: float):
    """One (m, t) tile: LME over samples for every parallelism level."""
    f = samples_ref[...]  # [1, S]
    theta = thetas_ref[0]  # scalar
    ys = jnp.arange(1, max_y + 1, dtype=f.dtype)  # [Y]
    scale = ys**alpha  # [Y]
    z = (-theta) * f / scale[:, None]  # [Y, S]
    zmax = jnp.max(z, axis=1, keepdims=True)  # [Y, 1]
    lme = zmax[:, 0] + jnp.log(jnp.mean(jnp.exp(z - zmax), axis=1))
    out_ref[...] = lme[None, None, :]  # [1, 1, Y]


@functools.partial(jax.jit, static_argnames=("max_y", "alpha"))
def effcap_lme(samples: jax.Array, thetas: jax.Array, *, max_y: int, alpha: float):
    """Pallas-tiled LME sweep.

    Args:
      samples: ``f32[M, S]`` iid uncontended service-rate draws per MS.
      thetas:  ``f32[T]`` QoS exponents (log-spaced grid).
      max_y:   maximum parallelism level Y (static).
      alpha:   contention exponent (static); per-task rate is ``f / y**alpha``.

    Returns:
      ``f32[M, T, Y]`` log-mean-exp values.
    """
    m, s = samples.shape
    (t,) = thetas.shape
    kernel = functools.partial(_lme_kernel, max_y=max_y, alpha=alpha)
    return pl.pallas_call(
        kernel,
        grid=(m, t),
        in_specs=[
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 1, max_y), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, t, max_y), samples.dtype),
        interpret=True,
    )(samples, thetas)
