"""Layer-1 Pallas kernel: QoS-score apportionment (eqs. 15-16).

Rows are (user, task-type, core-MS) tuples; for each row the kernel
computes the softmax load apportionment over candidate nodes and the
clamped urgency ratio, then scatters both into per-(node, core) matrices
through a one-hot group matrix using matmuls (the MXU-facing part):

    W[r, v]    = exp(-delta * (dpr[r, v] - min_v dpr[r, :])) / row_sum
    zt[v, c]  += sum_r W[r, v] * z[r] * G[r, c]
    ratio[r,v] = clip((D[r] - dpr[r, v] - dcu[r]) / dsu[r], lo, hi)
    dt[v, c]  += sum_r ratio[r, v] * G[r, c]

Zero-padded rows (z = 0 and G = 0) contribute nothing, so the Rust
runtime can pad to the AOT-compiled shape freely.

TPU shape rationale: the grid walks row tiles; each program holds a
[Rt, V] tile plus the [V, C] accumulators in VMEM and performs two
[V, Rt] x [Rt, C] matmuls per tile — MXU-shaped work — accumulating
across the sequential grid axis with a first-iteration initializer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["qos_apportion"]


def _qos_kernel(
    dpr_ref,
    z_ref,
    dd_ref,
    dcu_ref,
    dsu_ref,
    group_ref,
    zt_ref,
    dt_ref,
    *,
    delta: float,
    lo: float,
    hi: float,
):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        zt_ref[...] = jnp.zeros_like(zt_ref)
        dt_ref[...] = jnp.zeros_like(dt_ref)

    dpr = dpr_ref[...]  # [Rt, V]
    z = z_ref[...]  # [Rt]
    dd = dd_ref[...]  # [Rt]
    dcu = dcu_ref[...]  # [Rt]
    dsu = dsu_ref[...]  # [Rt]
    group = group_ref[...]  # [Rt, C]

    # eq. (15): exponential-decay softmax over nodes (max-shifted).
    shifted = -delta * (dpr - jnp.min(dpr, axis=1, keepdims=True))
    w = jnp.exp(shifted)
    w = w / jnp.sum(w, axis=1, keepdims=True)  # [Rt, V]
    weighted = group * z[:, None]  # [Rt, C]
    zt_ref[...] += jnp.dot(w.T, weighted)  # [V, C]

    # eq. (16): clamped urgency ratio.
    ratio = jnp.clip((dd[:, None] - dpr - dcu[:, None]) / dsu[:, None], lo, hi)
    dt_ref[...] += jnp.dot(ratio.T, group)  # [V, C]


@functools.partial(jax.jit, static_argnames=("delta", "lo", "hi", "row_tile"))
def qos_apportion(
    dpr: jax.Array,
    z: jax.Array,
    deadlines: jax.Array,
    dcu: jax.Array,
    dsu: jax.Array,
    group: jax.Array,
    *,
    delta: float,
    lo: float,
    hi: float,
    row_tile: int = 64,
):
    """Pallas-tiled apportionment.

    Args:
      dpr:       ``f32[R, V]`` preceding latency of row r at node v.
      z:         ``f32[R]`` mean arrival rate of the row (0 = padding).
      deadlines: ``f32[R]`` task-type deadline D_n.
      dcu:       ``f32[R]`` current-node mean processing delay.
      dsu:       ``f32[R]`` successor mean processing (>= small floor).
      group:     ``f32[R, C]`` one-hot row -> core-MS matrix (0 = padding).
      delta:     decay rate of eq. (15).
      lo, hi:    urgency clamp (C1 floor and the numerical cap).
      row_tile:  rows per grid step (R must divide evenly after padding).

    Returns:
      ``(zt, dt)`` both ``f32[V, C]``; the QoS score is ``zt * dt``.
    """
    r, v = dpr.shape
    c = group.shape[1]
    assert r % row_tile == 0, f"pad rows to a multiple of {row_tile}"
    kernel = functools.partial(_qos_kernel, delta=delta, lo=lo, hi=hi)
    grid = (r // row_tile,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, v), lambda i: (i, 0)),
            pl.BlockSpec((row_tile,), lambda i: (i,)),
            pl.BlockSpec((row_tile,), lambda i: (i,)),
            pl.BlockSpec((row_tile,), lambda i: (i,)),
            pl.BlockSpec((row_tile,), lambda i: (i,)),
            pl.BlockSpec((row_tile, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((v, c), lambda i: (0, 0)),
            pl.BlockSpec((v, c), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((v, c), dpr.dtype),
            jax.ShapeDtypeStruct((v, c), dpr.dtype),
        ],
        interpret=True,
    )(dpr, z, deadlines, dcu, dsu, group)
