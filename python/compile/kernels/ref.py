"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package has a straight-line jnp twin here;
pytest sweeps shapes/dtypes (hypothesis included) and asserts allclose.
"""

from __future__ import annotations

import jax.numpy as jnp


def effcap_lme_ref(samples, thetas, *, max_y: int, alpha: float):
    """Reference for ``effcap.effcap_lme``: f32[M,S],f32[T] -> f32[M,T,Y]."""
    ys = jnp.arange(1, max_y + 1, dtype=samples.dtype)
    scale = ys**alpha  # [Y]
    # [M, T, Y, S]
    z = -thetas[None, :, None, None] * samples[:, None, None, :] / scale[None, None, :, None]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    return (zmax[..., 0] + jnp.log(jnp.mean(jnp.exp(z - zmax), axis=-1))).astype(
        samples.dtype
    )


def qos_apportion_ref(dpr, z, deadlines, dcu, dsu, group, *, delta, lo, hi):
    """Reference for ``qos.qos_apportion``."""
    shifted = -delta * (dpr - jnp.min(dpr, axis=1, keepdims=True))
    w = jnp.exp(shifted)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    zt = w.T @ (group * z[:, None])
    ratio = jnp.clip((deadlines[:, None] - dpr - dcu[:, None]) / dsu[:, None], lo, hi)
    dt = ratio.T @ group
    return zt, dt


def gamma_effective_capacity(shape, scale, theta):
    """Closed form E^c(theta) = k*ln(1+theta*s)/theta for Gamma(k, s) —
    the analytic oracle shared with rust (rng::Gamma::effective_capacity)."""
    return shape * jnp.log1p(theta * scale) / theta
