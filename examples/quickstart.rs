//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Builds a paper-default environment, places core services with the
//! static tier, runs the online controller for a short horizon, and
//! prints the paper's two headline metrics.
//!
//! Run: `cargo run --release --example quickstart [-- --slots N]`
//! (`--slots` shrinks the horizon — CI smoke-runs it tiny.)

use fmedge::baselines::Proposal;
use fmedge::cli::Args;
use fmedge::config::ExperimentConfig;
use fmedge::sim::{run_trial, SimEnv, SimOptions};

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    // 1. Configuration — Table I defaults; tweak anything via TOML or code.
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = args.get_usize("slots", 300).unwrap_or(300);
    println!("{}", cfg.describe());

    // 2. Environment — application (Fig. 1), topology (Fig. 2), users,
    //    effective-capacity tables, all sampled from the config ranges.
    let env = SimEnv::build(&cfg, cfg.sim.seed);
    println!(
        "environment: {} nodes, {} core + {} light services, {} task types",
        env.topo.num_nodes(),
        env.app.catalog.num_core(),
        env.app.catalog.num_light(),
        env.app.task_types.len()
    );

    // 3. One trial of the paper's two-tier proposal.
    let metrics = run_trial(
        &env,
        &mut Proposal::new(),
        cfg.sim.seed,
        &SimOptions::from_config(&cfg),
    );

    println!(
        "\ntasks admitted    {}\ncompletion rate   {:.1}%\non-time rate      {:.1}%  (paper: >84%)\ntotal cost        {:.0} (core {:.0} / light {:.0})\nlatency p50/p95   {:.1} / {:.1} ms",
        metrics.total_tasks,
        100.0 * metrics.completion_rate(),
        100.0 * metrics.on_time_rate(),
        metrics.total_cost,
        metrics.core_cost,
        metrics.light_cost,
        metrics.latency_percentile(0.5),
        metrics.latency_percentile(0.95),
    );
}
