//! Ablation sweep over the paper's design knobs: the virtual-queue floor
//! ζ (the paper's departure from vanilla drift-plus-penalty, eq. 18), the
//! diversity minimum κ (C6), and the violation budget ε of the
//! effective-capacity map.
//!
//! Run: `cargo run --release --example ablation_sweep`

use fmedge::baselines::Proposal;
use fmedge::config::ExperimentConfig;
use fmedge::metrics::Summary;
use fmedge::sim::{run_trial, SimEnv, SimOptions};

fn run_point(cfg: &ExperimentConfig, trials: usize) -> (f64, f64, f64) {
    let mut otr = Vec::new();
    let mut cost = Vec::new();
    for t in 0..trials {
        let seed = cfg.sim.seed + t as u64;
        let env = SimEnv::build(cfg, seed);
        let mut opts = SimOptions::from_config(cfg);
        opts.load_multiplier = 1.5; // stress regime: ablations matter here
        let m = run_trial(&env, &mut Proposal::new(), seed, &opts);
        otr.push(m.on_time_rate());
        cost.push(m.total_cost);
    }
    let s = Summary::of(&otr);
    (s.mean, s.std, Summary::of(&cost).mean)
}

fn main() {
    let mut base = ExperimentConfig::paper_default();
    base.sim.slots = 300;
    let trials = 4;

    println!("## ζ — virtual-queue floor (eq. 18)\n");
    println!("| zeta | on-time | std | cost |");
    println!("|---|---|---|---|");
    for zeta in [0.0, 0.25, 0.5, 1.0, 2.0] {
        let mut cfg = base.clone();
        cfg.controller.zeta = zeta;
        let (m, s, c) = run_point(&cfg, trials);
        println!("| {zeta} | {m:.3} | {s:.3} | {c:.0} |");
    }

    println!("\n## κ — minimum distinct core deployments (C6)\n");
    println!("| kappa | on-time | std | cost |");
    println!("|---|---|---|---|");
    for kappa in [2usize, 6, 8, 12, 16] {
        let mut cfg = base.clone();
        cfg.controller.kappa = kappa;
        let (m, s, c) = run_point(&cfg, trials);
        println!("| {kappa} | {m:.3} | {s:.3} | {c:.0} |");
    }

    println!("\n## ε — latency-violation budget of g_(m,eps)(y)\n");
    println!("| epsilon | on-time | std | cost |");
    println!("|---|---|---|---|");
    for eps in [0.05, 0.1, 0.2, 0.4] {
        let mut cfg = base.clone();
        cfg.controller.epsilon = eps;
        let (m, s, c) = run_point(&cfg, trials);
        println!("| {eps} | {m:.3} | {s:.3} | {c:.0} |");
    }
}
