//! Compare all four deployment strategies on identical environments —
//! a console miniature of Fig. 3 (run `cargo bench --bench bench_fig3`
//! for the full violin distributions).
//!
//! Run: `cargo run --release --example compare_strategies`

use fmedge::baselines::{GaStrategy, LbrrStrategy, PropAvg, Proposal};
use fmedge::config::ExperimentConfig;
use fmedge::metrics::Summary;
use fmedge::sim::{run_trial, SimEnv, SimOptions, Strategy};

fn main() {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 400;
    cfg.sim.trials = 8;

    println!(
        "{} trials × {} slots, load ×{}\n",
        cfg.sim.trials, cfg.sim.slots, cfg.sim.load_multiplier
    );
    println!(
        "| strategy | on-time mean | on-time std | cost mean | cost std |"
    );
    println!("|---|---|---|---|---|");
    for name in ["Proposal", "PropAvg", "LBRR", "GA"] {
        let mut otr = Vec::new();
        let mut cost = Vec::new();
        for trial in 0..cfg.sim.trials {
            let seed = cfg.sim.seed + trial as u64;
            let env = SimEnv::build(&cfg, seed);
            let mut s: Box<dyn Strategy> = match name {
                "Proposal" => Box::new(Proposal::new()),
                "PropAvg" => Box::new(PropAvg::new()),
                "LBRR" => Box::new(LbrrStrategy::new()),
                _ => Box::new(GaStrategy::new(16, 12)),
            };
            let m = run_trial(&env, s.as_mut(), seed, &SimOptions::from_config(&cfg));
            otr.push(m.on_time_rate());
            cost.push(m.total_cost);
        }
        let so = Summary::of(&otr);
        let sc = Summary::of(&cost);
        println!(
            "| {name} | {:.3} | {:.3} | {:.0} | {:.0} |",
            so.mean, so.std, sc.mean, sc.std
        );
    }
    println!("\nExpected shape (paper §IV): the proposal pairs a high, tight");
    println!("on-time distribution with moderate cost; LBRR/GA trade QoS for");
    println!("cost and collapse under load (see bench_fig4).");
}
