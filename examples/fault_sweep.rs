//! Fault-injection demo (EXPERIMENTS §P4): replay one recorded trace
//! under increasingly hostile seeded fault schedules and watch the
//! proposal's on-time completion degrade — on both engines, plus the
//! static backbone's survival score under the same outages.
//!
//! Run: `cargo run --release --example fault_sweep`
//! Options: `-- --slots N --seed N --load X --rates R1,R2,...`

use fmedge::baselines::{LbrrStrategy, Proposal};
use fmedge::cli::Args;
use fmedge::config::ExperimentConfig;
use fmedge::des::{run_des_trial_faulted, DesOptions};
use fmedge::faults::{FaultKind, FaultParams, FaultSchedule};
use fmedge::placement::{placement_under_failure, QosScores, ScoreParams};
use fmedge::rng::Xoshiro256;
use fmedge::sim::{record_trace, run_trial_faulted, SimEnv, SimOptions, Strategy};
use fmedge::workload::WorkloadGenerator;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = args.get_usize("slots", 200).unwrap_or(200);
    cfg.sim.load_multiplier = args.get_f64("load", 1.5).unwrap_or(1.5);
    let seed = args.get_u64("seed", 2026).unwrap_or(2026);
    let rates = args
        .get_f64_list("rates", &[0.0, 0.002, 0.005, 0.02])
        .unwrap_or_else(|_| vec![0.0, 0.002, 0.005, 0.02]);

    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    println!(
        "fault sweep: {} tasks over {} slots at load x{}, seed {seed}",
        trace.len(),
        opts.slots,
        cfg.sim.load_multiplier
    );

    println!(
        "\n{:<10} {:>9} {:>16} {:>14} {:>13} {:>13}",
        "fail rate", "events", "slotted on-time", "DES on-time", "LBRR slotted", "fault drops"
    );
    for &rate in &rates {
        let schedule = if rate > 0.0 {
            FaultSchedule::generate(
                &env.topo,
                opts.slots,
                opts.slot_ms,
                env.app.catalog.num_core(),
                &FaultParams::from_rate(rate),
                seed ^ rate.to_bits(),
            )
        } else {
            FaultSchedule::none()
        };
        let slotted = run_trial_faulted(
            &env,
            &mut Proposal::new(),
            seed,
            &opts,
            &trace,
            &schedule,
        );
        let des = run_des_trial_faulted(
            &env,
            &mut Proposal::new(),
            seed,
            &DesOptions::from_sim(&opts),
            &trace,
            &schedule,
        );
        let lbrr = run_trial_faulted(
            &env,
            &mut LbrrStrategy::new(),
            seed,
            &opts,
            &trace,
            &schedule,
        );
        println!(
            "{:<10.4} {:>9} {:>16.3} {:>14.3} {:>13.3} {:>13}",
            rate,
            schedule.len(),
            slotted.on_time_rate(),
            des.on_time_rate(),
            lbrr.on_time_rate(),
            slotted.fault_drops + des.fault_drops
        );
    }

    // Backbone survival: score the proposal's static placement under the
    // worst concurrent-outage set any generated schedule reaches.
    let gen = WorkloadGenerator::new(
        &cfg,
        &env.app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );
    let scores = QosScores::compute(
        &env.app,
        &env.topo,
        &env.dm,
        gen.users(),
        &ScoreParams::from_config(&cfg.controller),
    );
    let placement = Proposal::new().place_core(&env, &scores, &mut Xoshiro256::seed_from(seed));
    let schedule = FaultSchedule::generate(
        &env.topo,
        opts.slots,
        opts.slot_ms,
        env.app.catalog.num_core(),
        &FaultParams::from_rate(*rates.last().unwrap_or(&0.02)),
        seed ^ 0xBACC_B04E,
    );
    let mut down = vec![false; env.topo.num_nodes()];
    let mut worst_frac = 1.0f64;
    let mut worst_lost = 0usize;
    for ev in schedule.events() {
        match ev.kind {
            FaultKind::NodeDown { node } => down[node] = true,
            FaultKind::NodeUp { node } => down[node] = false,
            _ => {}
        }
        let impact = placement_under_failure(&placement.instances, &scores, &down);
        if impact.survival_fraction() < worst_frac {
            worst_frac = impact.survival_fraction();
            worst_lost = impact.services_lost;
        }
    }
    println!(
        "\nbackbone under the harshest outage set: {:.1}% of QoS-weighted value survives, {} core service(s) lost",
        100.0 * worst_frac,
        worst_lost
    );
}
