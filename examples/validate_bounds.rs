//! Measured-vs-analytic bound validation on a paired trace (EXPERIMENTS
//! §P2): record one realized workload, run the *slotted* engine (which
//! assumes the effective-capacity bound `g_{m,ε}(y)`) and the *DES*
//! engine (which measures real per-replica queueing) on the same trace,
//! and report per-light-service empirical violation rates against ε.
//!
//! Run: `cargo run --release --example validate_bounds`
//! Options: `-- --seeds N --slots N --epsilon X --load X`

use fmedge::baselines::Proposal;
use fmedge::cli::Args;
use fmedge::config::ExperimentConfig;
use fmedge::des::{pool, report, run_des_trial, validate_bounds, DesOptions};
use fmedge::sim::{record_trace, run_trial_traced, SimEnv, SimOptions};

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let seeds = args.get_usize("seeds", 3).unwrap_or(3);
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = args.get_usize("slots", 300).unwrap_or(300);
    cfg.controller.epsilon = args.get_f64("epsilon", cfg.controller.epsilon).unwrap();
    cfg.sim.load_multiplier = args.get_f64("load", cfg.sim.load_multiplier).unwrap();
    println!(
        "bound validation: eps={} slots={} load={} seeds={seeds}",
        cfg.controller.epsilon, cfg.sim.slots, cfg.sim.load_multiplier
    );

    let mut per_trial = Vec::new();
    println!("\nseed   tasks   on-time slotted   on-time DES   sojourns measured");
    for i in 0..seeds {
        let seed = cfg.sim.seed + i as u64;
        let env = SimEnv::build(&cfg, seed);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, seed, &opts);

        // Paired comparison: both engines admit exactly this workload.
        let slotted = run_trial_traced(&env, &mut Proposal::new(), seed, &opts, &trace);
        let des = run_des_trial(
            &env,
            &mut Proposal::new(),
            seed,
            &DesOptions::from_sim(&opts),
            &trace,
        );
        let measured: usize = des.service_obs.iter().map(|o| o.samples.len()).sum();
        println!(
            "{seed:<6} {:<7} {:<17.3} {:<13.3} {measured}",
            des.total_tasks,
            slotted.on_time_rate(),
            des.on_time_rate(),
        );
        per_trial.push(validate_bounds(&env.gtable, &des));
    }

    let pooled = pool(&per_trial);
    println!(
        "\nmeasured P(sojourn > g_{{m,eps}}(y)) per light service, eps={} (pooled over {} seeds):",
        cfg.controller.epsilon, seeds
    );
    println!("{}", report(&pooled));

    let total: usize = pooled.iter().map(|v| v.samples).sum();
    let violations: usize = pooled.iter().map(|v| v.violations).sum();
    let worst = pooled
        .iter()
        .filter(|v| v.samples > 0)
        .map(|v| v.violation_rate())
        .fold(0.0f64, f64::max);
    let all_hold = pooled.iter().all(|v| v.holds(0.0));
    println!(
        "aggregate: {}/{} violations ({:.4}); worst service {:.4}; guarantee {} at eps={}",
        violations,
        total,
        if total > 0 {
            violations as f64 / total as f64
        } else {
            0.0
        },
        worst,
        if all_hold { "HOLDS" } else { "VIOLATED" },
        cfg.controller.epsilon
    );
}
