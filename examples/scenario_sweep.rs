//! Scenario-library tour (EXPERIMENTS §P5): compile every scenario family
//! — non-stationary arrivals (diurnal, MMPP, flash crowd), user mobility
//! (random waypoint, commuter), and correlated outages (zone/rack,
//! cascading links, load-correlated fail-stop) — against one environment
//! and replay each under BOTH engines.
//!
//! Run: `cargo run --release --example scenario_sweep`
//! Options: `-- --slots N --seed N --load X --scenarios a,b,...`
//! (full grids with CIs: `fmedge sweep --experiment p5`)

use fmedge::baselines::Proposal;
use fmedge::cli::Args;
use fmedge::config::ExperimentConfig;
use fmedge::des::{run_des_trial_faulted, DesOptions};
use fmedge::scenarios::ScenarioSpec;
use fmedge::sim::{run_trial_faulted, SimEnv, SimOptions};

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let mut cfg = ExperimentConfig::paper_default();
    // 400 slots -> arrivals run to slot 250 (the tail drains), wide
    // enough that the flash crowd, commuter flips, and rush hour all
    // land inside the arrival window; at 200 slots a third of the
    // library would degenerate to the baseline.
    cfg.sim.slots = args.get_usize("slots", 400).unwrap_or(400);
    cfg.sim.load_multiplier = args.get_f64("load", 1.0).unwrap_or(1.0);
    let seed = args.get_u64("seed", 2026).unwrap_or(2026);
    let names = args.get_str_list("scenarios", &[]);
    let specs: Vec<ScenarioSpec> = if names.is_empty() {
        ScenarioSpec::library()
    } else {
        names
            .iter()
            .filter_map(|n| {
                let s = ScenarioSpec::by_name(n);
                if s.is_none() {
                    eprintln!("warning: unknown scenario `{n}` skipped");
                }
                s
            })
            .collect()
    };

    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    println!(
        "scenario tour: {} families over {} slots at load x{}, seed {seed}",
        specs.len(),
        opts.slots,
        cfg.sim.load_multiplier
    );
    println!(
        "\n{:<12} {:>7} {:>7} {:>6} {:>16} {:>12} {:>12}",
        "scenario", "tasks", "faults", "moves", "slotted on-time", "DES on-time", "fault drops"
    );
    for spec in &specs {
        let cs = spec.compile(&env, &opts, seed);
        let slotted = run_trial_faulted(
            &env,
            &mut Proposal::new(),
            seed,
            &opts,
            &cs.trace,
            &cs.faults,
        );
        let des = run_des_trial_faulted(
            &env,
            &mut Proposal::new(),
            seed,
            &DesOptions::from_sim(&opts),
            &cs.trace,
            &cs.faults,
        );
        println!(
            "{:<12} {:>7} {:>7} {:>6} {:>16.3} {:>12.3} {:>12}",
            spec.name,
            cs.trace.len(),
            cs.faults.len(),
            cs.user_moves,
            slotted.on_time_rate(),
            des.on_time_rate(),
            slotted.fault_drops + des.fault_drops
        );
    }
    println!("\nfull grids with 95% CIs: fmedge sweep --experiment p5 --threads 4 --out p5.csv");
}
