//! End-to-end serving driver — proves all three layers compose.
//!
//! 1. **Plan (Layers 1+2 via PJRT):** load `effcap.hlo.txt` (the Pallas
//!    log-mean-exp kernel inside the JAX delay-bound graph) and build the
//!    `g_{m,ε}(y)` table through the PJRT runtime — cross-checked against
//!    the native implementation.
//! 2. **Simulate (Layer 3):** run the two-tier controller on a recorded
//!    workload trace with the PJRT-built table on the decision path.
//! 3. **Serve (Layer 3 + PJRT on the request path):** replay the same
//!    trace's arrival process against the serving coordinator, executing
//!    the real `msblock.hlo.txt` transformer block per batched request,
//!    and report latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example serve_trace`
//! (results recorded in EXPERIMENTS.md §End-to-end.)

use std::time::Instant;

use fmedge::baselines::Proposal;
use fmedge::config::ExperimentConfig;
use fmedge::coordinator::{BatchPolicy, Coordinator, Request, ServeConfig};
use fmedge::rng::{Rng, Xoshiro256};
use fmedge::runtime::{shapes, EffCapAccel, Runtime};
use fmedge::sim::{run_trial, SimEnv, SimOptions};
use fmedge::workload::{Trace, WorkloadGenerator};

fn main() {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 400;

    // ---------------------------------------------------------------- plan
    let t0 = Instant::now();
    let rt = match Runtime::cpu(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("[plan] PJRT platform: {}", rt.platform());
    let env = SimEnv::build(&cfg, cfg.sim.seed);
    let workloads: Vec<f64> = env
        .app
        .catalog
        .light_ids()
        .iter()
        .map(|&m| env.app.catalog.spec(m).workload_mb)
        .collect();
    let accel = EffCapAccel::load(&rt).expect("effcap artifact");
    let t_native = Instant::now();
    let native_g = env.gtable.clone();
    let native_ms = t_native.elapsed();
    let t_pjrt = Instant::now();
    let gtable = accel
        .build_gtable(&env.light_rate_samples, &workloads)
        .expect("PJRT g-table");
    let pjrt_ms = t_pjrt.elapsed();
    let mut max_rel = 0.0f64;
    for m in 0..gtable.num_ms() {
        for y in 1..=gtable.max_parallelism() {
            let (a, b) = (native_g.delay(m, y), gtable.delay(m, y));
            max_rel = max_rel.max((a - b).abs() / a.max(1e-9));
        }
    }
    println!(
        "[plan] g-table built via PJRT in {pjrt_ms:?} (native {native_ms:?}); max |Δ|/g = {max_rel:.2e}"
    );
    println!("[plan] startup total {:?}", t0.elapsed());

    // ------------------------------------------------------------ simulate
    let mut gen = WorkloadGenerator::new(
        &cfg,
        &env.app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );
    let mut arrivals = Vec::new();
    let mut rng = Xoshiro256::seed_from(2026);
    let opts = SimOptions::from_config(&cfg);
    for t in 0..opts.arrival_cutoff {
        arrivals.extend(gen.generate_slot(t, 1.0, &mut rng));
    }
    let trace = Trace::from_arrivals(arrivals);
    println!(
        "\n[sim] recorded trace: {} tasks over {} slots",
        trace.len(),
        trace.num_slots()
    );
    let env = env.with_gtable(gtable);
    let t_sim = Instant::now();
    let m = run_trial(&env, &mut Proposal::new(), cfg.sim.seed, &opts);
    println!(
        "[sim] {} tasks, completion {:.1}%, on-time {:.1}%, cost {:.0}, p50/p95 latency {:.1}/{:.1} ms ({:?})",
        m.total_tasks,
        100.0 * m.completion_rate(),
        100.0 * m.on_time_rate(),
        m.total_cost,
        m.latency_percentile(0.5),
        m.latency_percentile(0.95),
        t_sim.elapsed()
    );

    // --------------------------------------------------------------- serve
    // Replay the trace's arrival process against the live coordinator with
    // real PJRT compute per request. 1 simulated ms -> `time_scale` wall ms
    // keeps the open-loop rate within CPU serving capacity.
    let time_scale = 100.0; // ~360 rps offered at the trace's arrival rate
    let requests: usize = 1200.min(trace.len());
    let coordinator = Coordinator::start(ServeConfig {
        workers: 3,
        batch: BatchPolicy::default(),
        ..Default::default()
    })
    .expect("coordinator start");
    // Warm up the PJRT executables before timing.
    std::thread::sleep(std::time::Duration::from_millis(300));
    let slot_len = shapes::MSBLOCK_L * shapes::MSBLOCK_D;
    let mut rng = Xoshiro256::seed_from(99);
    let t_serve = Instant::now();
    let mut submitted = 0usize;
    let mut rejected = 0usize;
    'outer: for slot in 0..trace.num_slots() {
        for a in trace.slot(slot) {
            if submitted >= requests {
                break 'outer;
            }
            let data: Vec<f32> = (0..slot_len).map(|_| rng.next_f64() as f32).collect();
            let req = Request {
                id: a.id.0,
                data,
                submitted: Instant::now(),
                deadline_ms: 50.0,
            };
            match coordinator.submit(req) {
                Ok(()) => submitted += 1,
                Err(_) => rejected += 1,
            }
        }
        // Pace: one simulated slot per time_scale wall-milliseconds (sleep
        // until this slot's wall-clock target so arrivals are not bursty).
        let target = std::time::Duration::from_secs_f64(
            (slot as f64 + 1.0) * time_scale / 1e3,
        );
        if let Some(remaining) = target.checked_sub(t_serve.elapsed()) {
            std::thread::sleep(remaining);
        }
    }
    let report = coordinator.shutdown();
    println!(
        "\n[serve] replayed {} requests ({} backpressured) in {:?}",
        submitted, rejected, report.elapsed
    );
    println!(
        "[serve] throughput {:.0} rps | batch fill {:.2} | on-time(50ms) {:.1}%",
        report.throughput_rps(),
        report.batch_fill,
        100.0 * report.on_time_rate()
    );
    println!("[serve] latency (ms): {}", report.latency_ms.row());
    println!("\nAll three layers composed: Pallas kernel → JAX graph → HLO →");
    println!("PJRT executables on the Rust planning *and* request paths.");
}
