//! End-to-end failover demo: the serving path survives a zone outage.
//!
//! Three views of the same robustness layer:
//!   1. the deterministic virtual-time serving replay (`ReplayServer`)
//!      under an edge-zone outage — run twice to show the failover
//!      counters are bit-stable,
//!   2. both simulation engines replaying one seeded fault schedule with
//!      checkpoint/restart-paired replica fail-stops, reporting the
//!      retry/re-route/restore ledger next to the casualty count,
//!   3. the degradation contract: accepted work is either served or
//!      provably payload-destroyed — nothing is silently dropped.
//!
//! Run: `cargo run --release --example failover_demo`
//! Options: `-- --slots N --seed N --load X --outage-ms D`

use fmedge::baselines::Proposal;
use fmedge::cli::Args;
use fmedge::config::ExperimentConfig;
use fmedge::coordinator::{
    parse_fault_spec, FailoverPolicy, ReplayConfig, ReplayServer, VirtualRequest,
};
use fmedge::des::{run_des_trial_faulted, DesOptions};
use fmedge::faults::{FaultParams, FaultSchedule};
use fmedge::metrics::TrialMetrics;
use fmedge::sim::{record_trace, run_trial_faulted, SimEnv, SimOptions};

fn ledger(name: &str, m: &TrialMetrics) {
    println!(
        "{:<8} on-time {:.3}  completed {}/{}  retries {}  rerouted {}  hedges {}  restores {}  payload-destroyed {}",
        name,
        m.on_time_rate(),
        m.completed,
        m.total_tasks,
        m.retries,
        m.reroute_recovered,
        m.hedges,
        m.checkpoint_restores,
        m.fault_drops
    );
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = args.get_usize("slots", 300).unwrap_or(300);
    cfg.sim.load_multiplier = args.get_f64("load", 1.5).unwrap_or(1.5);
    let seed = args.get_u64("seed", 2026).unwrap_or(2026);
    let outage_ms = args.get_f64("outage-ms", 60.0).unwrap_or(60.0);
    let (num_eds, num_ess) = (cfg.network.num_eds, cfg.network.num_ess);

    // -- 1. Virtual-time serving replay: a zone outage mid-run ----------
    let spec = format!("zone@40+{outage_ms}");
    let schedule = parse_fault_spec(&spec, num_eds, num_ess).expect("spec");
    let rcfg = ReplayConfig {
        workers: 4,
        ..Default::default()
    };
    let server = ReplayServer::new(rcfg, &schedule, num_eds);
    let arrivals: Vec<VirtualRequest> = (0..600)
        .map(|id| VirtualRequest {
            id,
            arrive_ms: id as f64 * 0.5,
            deadline_ms: 50.0,
        })
        .collect();
    let a = server.run(&arrivals);
    let b = server.run(&arrivals);
    println!("virtual serve under `{spec}` ({} workers):", 4);
    println!(
        "  accepted {}  served {}  on-time {}  {}",
        a.accepted,
        a.served,
        a.on_time,
        a.stats.line()
    );
    assert_eq!(a.stats, b.stats, "failover counters must be bit-stable");
    assert_eq!(a.served, b.served);
    assert_eq!(
        a.stats.abandoned, 0,
        "degradation contract: accepted work is never abandoned"
    );
    println!("  second run: identical counters (bit-deterministic) ✓\n");

    // -- 2. Both engines replay one schedule with paired restarts -------
    let env = SimEnv::build(&cfg, seed);
    let mut opts = SimOptions::from_config(&cfg);
    // Tighter checkpoint cadence so restores are visible in a short run.
    opts.failover.checkpoint.period_ms = 20.0;
    let trace = record_trace(&env, seed, &opts);
    let params = FaultParams::from_rate(0.01).with_replica_restart(25.0);
    let faults = FaultSchedule::generate(
        &env.topo,
        opts.slots,
        opts.slot_ms,
        env.app.catalog.num_core(),
        &params,
        seed,
    );
    println!(
        "engine replay: {} tasks, {} fault events (replica fail-stops paired with restarts)",
        trace.len(),
        faults.len()
    );
    let slotted =
        run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &faults);
    let des = run_des_trial_faulted(
        &env,
        &mut Proposal::new(),
        seed,
        &DesOptions::from_sim(&opts),
        &trace,
        &faults,
    );
    ledger("slotted", &slotted);
    ledger("des", &des);

    // -- 3. The degradation contract, stated on the numbers -------------
    let accounted = slotted.completed + slotted.fault_drops;
    println!(
        "\ncontract: {} completed + {} payload-destroyed = {} of {} admitted accounted for",
        slotted.completed, slotted.fault_drops, accounted, slotted.total_tasks
    );
    println!(
        "(the remainder, {}, aged out past {}x their deadline under outage pressure — \
         dropped by the age bound, not silently lost)",
        slotted.total_tasks - accounted,
        opts.drop_after_deadlines
    );
    let _ = FailoverPolicy::default(); // the policy object both paths share
}
